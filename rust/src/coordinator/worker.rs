//! Batch execution: fuse a batch of requests into one forward pass (PJRT
//! artifact call, or a native compiled [`crate::plan::ExecPlan`] — one
//! uniform path for every native task), then scatter replies.
//!
//! Fault containment (DESIGN.md §11): [`execute_batch`] owns the
//! terminal outcome of every request it is handed — each one receives
//! exactly one `Ok(Response)` or `Err(ServeError)` on its reply channel.
//! A malformed row discovered at gather time fails *only that request*
//! (the rest of the batch still executes); a backend error fails the
//! batch's requests with [`ServeError::BatchFailed`] instead of
//! dropping their channels. The worker loop runs each batch under
//! `catch_unwind`, so even a panicking forward pass fails its requests
//! and keeps the thread draining — one poisoned request can never
//! shrink the worker pool.

use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::Instant;

use crate::metrics::flight::Stage;
use crate::metrics::span::{
    SpanOutcome, STAGE_BATCH_FORM, STAGE_FORWARD, STAGE_GATHER,
    STAGE_QUEUE_WAIT, STAGE_REPLY,
};
use crate::replay::event::EventBody;
use crate::replay::recorder::TraceSink;
use crate::tensor::Tensor;
use crate::workspace::{Workspace, WsHandle};

use super::engine::Observability;
use super::error::ServeError;
use super::residency::Residency;
use super::router::{Backend, Model, Payload, Request, Response};
use crate::plan::ExecPlan;

/// Per-worker observability context (DESIGN.md §12): the engine's
/// shared [`Observability`] bundle plus this worker's fixed coordinates.
/// Built once per worker thread — only when instrumentation is armed —
/// and borrowed per batch, so the disarmed hot path pays a single
/// `Option` null check (the trace-sink cost model).
pub struct ObsCtx<'a> {
    pub obs: &'a Observability,
    /// `Task::index()` of the model this worker serves (stage-histogram
    /// label axis).
    pub task: usize,
    /// Worker lane recorded in flight-recorder events.
    pub worker: u32,
}

/// Wall-clock span of the fused forward pass inside [`run_forward`]
/// (the plan/backend call only — batch gather stays in the `gather`
/// stage). `enter` keeps the *first* start and `exit` the *last* end,
/// so a bucket-split recursion folds into one contiguous span.
#[derive(Default)]
struct FwdSpan {
    start: Option<Instant>,
    end: Option<Instant>,
}

impl FwdSpan {
    fn enter(&mut self) {
        if self.start.is_none() {
            self.start = Some(Instant::now());
        }
    }

    fn exit(&mut self) {
        self.end = Some(Instant::now());
    }
}

/// What happened to one executed batch — the worker's counter feed and
/// telemetry record.
#[derive(Debug, Default)]
pub struct BatchOutcome {
    /// Compiled bucket the good rows ran in (0 if no row was runnable).
    pub bucket: usize,
    /// Requests answered with a `Response`.
    pub completed: usize,
    /// Requests answered with a typed `ServeError`.
    pub failed: usize,
    /// Outcomes whose delivery failed (client dropped its receiver).
    /// Counted *after* `before_reply` runs — read it from the return
    /// value, not the callback argument.
    pub dropped: usize,
    /// Batch-level backend error, when the forward pass itself failed
    /// (every runnable row was answered with `BatchFailed`).
    pub error: Option<String>,
}

/// Execute one batch on its model and deliver every requester's
/// terminal outcome.
///
/// Generate batches are padded with zero latents up to the compiled
/// bucket size (padded outputs are discarded); segment batches run at
/// their exact size. Rows are validated individually during gather:
/// an incompatible payload fails that request with
/// [`ServeError::Validation`] while the remaining rows execute
/// normally. Reply sends ignore disconnected receivers (a client that
/// timed out and dropped its channel) beyond counting them in
/// [`BatchOutcome::dropped`].
///
/// `before_reply` runs after execution but before any outcome is sent,
/// so engine counters are consistent the moment a client observes a
/// result. With a recording `sink`, each outcome is recorded — a
/// `Response` event with the output checksum, or a `Failed` event with
/// the error kind (trace format v3) — *before* the send, so the trace
/// is complete even if the client races the recorder to shutdown.
///
/// With an `obs` context, every request's `gather`/`forward`/`reply`
/// stage latencies land in the per-`(task, outcome)` histograms and its
/// `gather_start`/`forward_*`/terminal events in the flight recorder
/// (DESIGN.md §12).
///
/// `batch` is drained as outcomes are delivered: requests still in the
/// vector after a panic unwinds through this function have received no
/// outcome yet, which is exactly what the worker's supervision layer
/// needs to fail them (`spawn_workers`).
///
/// `hnd` is the executing worker's workspace handle: native forwards
/// draw padded-batch latents, batch image gathers, activations and GEMM
/// scratch from it, so the *pool* serves every steady-state checkout
/// (DESIGN.md §9 — `bytes_allocated` stays flat). What a batch still
/// heap-allocates, by design: the per-request reply tensors
/// (client-owned, they leave the engine) and small per-batch outcome
/// bookkeeping (a few `Vec`s of `n` elements).
pub fn execute_batch(model: &Model, batch: &mut Vec<Request>,
                     sink: Option<&TraceSink>, hnd: &mut WsHandle,
                     obs: Option<&ObsCtx>,
                     before_reply: impl FnOnce(&BatchOutcome))
                     -> BatchOutcome {
    execute_batch_with(model, None, batch, sink, hnd, obs, before_reply)
}

/// [`execute_batch`] with an explicit resident-plan handle from the
/// residency manager's `ensure` — passing the *ensured* handle (rather
/// than re-reading the model's slot) closes the race where a peer
/// model's reload evicts this model between `ensure` and execution.
#[allow(clippy::too_many_arguments)]
pub fn execute_batch_with(model: &Model, resident: Option<Arc<ExecPlan>>,
                          batch: &mut Vec<Request>,
                          sink: Option<&TraceSink>, hnd: &mut WsHandle,
                          obs: Option<&ObsCtx>,
                          before_reply: impl FnOnce(&BatchOutcome))
                          -> BatchOutcome {
    if model.take_injected_panic() {
        panic!("injected worker panic (Model::inject_panic_next_batch \
                test hook)");
    }
    // One plan handle for the whole batch: an eviction racing this
    // batch cannot pull the plan out from under the forward pass
    // (DESIGN.md §16). `None` for PJRT — and for a native model whose
    // plan is evicted with no residency manager to reload it, in which
    // case every row fails validation with a typed error.
    let plan = resident.or_else(|| model.plan_handle());
    let t_gather = Instant::now();
    if let Some(o) = obs {
        for r in batch.iter() {
            o.obs.flight.record(r.id, Stage::GatherStart, o.worker);
        }
    }
    // 1. Per-row gather validation: one malformed payload must fail one
    //    request, not the whole batch.
    let row_errs: Vec<Option<ServeError>> = batch
        .iter()
        .map(|r| validate_row(model, plan.as_deref(), r).err())
        .collect();
    let good: Vec<&Request> = batch
        .iter()
        .zip(&row_errs)
        .filter_map(|(r, e)| e.is_none().then_some(r))
        .collect();

    // 2. One fused forward pass over the good rows only.
    let bucket = if good.is_empty() {
        0
    } else {
        model.bucket_for(good.len())
    };
    let mut fwd_span = FwdSpan::default();
    let fwd: Option<Result<Tensor>> = (!good.is_empty()).then(|| {
        if let Some(o) = obs {
            for r in &good {
                o.obs.flight.record(r.id, Stage::ForwardStart, o.worker);
            }
        }
        let res = run_forward(model, plan.as_deref(), &good, bucket, hnd,
                              Some(&mut fwd_span));
        if let Some(o) = obs {
            for r in &good {
                o.obs.flight.record(r.id, Stage::ForwardEnd, o.worker);
            }
        }
        res
    });
    // Stage boundaries: `forward` is the span inside the plan/backend
    // call; batch-close → forward-start is `gather` (validation + row
    // copies). With no runnable row both collapse to zero-width here.
    let now = Instant::now();
    let fwd_start = fwd_span.start.unwrap_or(now);
    let fwd_end = fwd_span.end.unwrap_or(fwd_start);

    // 3. Assemble every request's outcome *before* counters and sends:
    //    a panic anywhere up to here leaves `batch` untouched for the
    //    supervisor, and the reply loop below cannot fail.
    let mut results: Vec<std::result::Result<Tensor, ServeError>> =
        Vec::with_capacity(batch.len());
    let error = match &fwd {
        Some(Err(e)) => Some(format!("{e:#}")),
        _ => None,
    };
    let mut gi = 0usize; // row index within the good subset
    for row_err in &row_errs {
        results.push(match row_err {
            Some(e) => Err(e.clone()),
            None => match &fwd {
                Some(Ok(out)) => {
                    let (_, h, w, c) = out.dims4();
                    let elems = h * w * c;
                    let data =
                        out.data()[gi * elems..(gi + 1) * elems].to_vec();
                    gi += 1;
                    Ok(Tensor::from_vec(&[1, h, w, c], data))
                }
                Some(Err(_)) => {
                    gi += 1;
                    Err(ServeError::BatchFailed(
                        error.clone().unwrap_or_default()))
                }
                None => unreachable!("good row without a forward pass"),
            },
        });
    }
    let mut outcome = BatchOutcome {
        bucket,
        completed: results.iter().filter(|r| r.is_ok()).count(),
        failed: results.iter().filter(|r| r.is_err()).count(),
        dropped: 0,
        error,
    };
    before_reply(&outcome);

    // 4. Deliver: drain lockstep with `results`, record-then-send.
    let n = results.len();
    for (req, res) in batch.drain(..).zip(results) {
        let latency = req.enqueued.elapsed();
        let id = req.id;
        let enq = req.enqueued;
        let stamps = req.stamps;
        let ok = res.is_ok();
        let delivered = match res {
            Ok(output) => {
                if let Some(s) = sink {
                    s.record(EventBody::Response {
                        id: req.id,
                        batch_size: n,
                        bucket,
                        latency_us: latency.as_micros() as u64,
                        checksum: output.checksum(),
                    });
                }
                req.reply
                    .send(Ok(Response {
                        id: req.id,
                        output,
                        latency,
                        batch_size: n,
                        bucket,
                    }))
                    .is_ok()
            }
            Err(e) => fail_request(req, e, sink),
        };
        if !delivered {
            outcome.dropped += 1;
        }
        // Stage accounting, after the send so `reply` covers delivery.
        if let Some(o) = obs {
            let sent = Instant::now();
            let (outc, stage) = if ok {
                (SpanOutcome::Completed, Stage::Completed)
            } else {
                (SpanOutcome::Failed, Stage::Failed)
            };
            o.obs.flight.record(id, stage, o.worker);
            let popped = stamps.popped.unwrap_or(enq);
            let batched = stamps.batched.unwrap_or(popped);
            let st = &o.obs.stages;
            st.record(o.task, outc, STAGE_QUEUE_WAIT,
                      popped.saturating_duration_since(enq));
            st.record(o.task, outc, STAGE_BATCH_FORM,
                      batched.saturating_duration_since(popped));
            st.record(o.task, outc, STAGE_GATHER,
                      fwd_start.saturating_duration_since(t_gather));
            st.record(o.task, outc, STAGE_FORWARD,
                      fwd_end.saturating_duration_since(fwd_start));
            st.record(o.task, outc, STAGE_REPLY,
                      sent.saturating_duration_since(fwd_end));
        }
    }
    outcome
}

/// Deliver a typed failure to one request: record the v3 `Failed` trace
/// event (when recording), then send. The single definition of the
/// failure-delivery sequence — the in-batch error path and the panic
/// supervisor both go through here, so event fields and delivery
/// semantics cannot drift apart. Returns `false` when the client had
/// already dropped its receiver (the caller counts it as `dropped`).
fn fail_request(req: Request, err: ServeError, sink: Option<&TraceSink>)
                -> bool {
    if let Some(s) = sink {
        s.record(EventBody::Failed {
            id: req.id,
            kind: err.kind().to_string(),
            reason: err.to_string(),
        });
    }
    req.reply.send(Err(err)).is_ok()
}

/// Validate one request's payload against the batch's execution form.
/// Kinds and geometry were checked at submit; this is the gather-time
/// backstop that keeps a malformed row — however it got here — from
/// failing its neighbours.
fn validate_row(model: &Model, plan: Option<&ExecPlan>, r: &Request)
                -> std::result::Result<(), ServeError> {
    match &model.backend {
        Backend::Pjrt(_) => match &r.payload {
            Payload::Latent { z, cond }
                if z.len() == model.z_dim
                    && cond.len() == model.cond_dim => Ok(()),
            other => Err(ServeError::Validation(format!(
                "{}: generate batch got an incompatible {} payload \
                 (model wants z_dim {} + cond_dim {})",
                model.name, other.kind(), model.z_dim, model.cond_dim))),
        },
        Backend::Native(_) | Backend::NativeSeg(_) => {
            let ie = match plan {
                Some(p) => p.in_elems(),
                None => {
                    return Err(ServeError::Validation(format!(
                        "{}: native backend without a resident plan",
                        model.name)));
                }
            };
            match &r.payload {
                Payload::Latent { z, cond }
                    if z.len() + cond.len() == ie => Ok(()),
                Payload::Image { tensor, .. }
                    if tensor.len() == ie => Ok(()),
                other => Err(ServeError::Validation(format!(
                    "{}: batch got an incompatible {} payload (plan \
                     wants {ie} input elements)",
                    model.name, other.kind()))),
            }
        }
    }
}

/// Pull the latent (+ conditioning) matrices out of a generate batch,
/// zero-padded to `bucket` rows (the PJRT input form). Rows were
/// validated by [`validate_row`]; a mismatch here is an engine bug.
fn gather_latents(model: &Model, batch: &[&Request], bucket: usize)
                  -> Result<(Tensor, Option<Tensor>)> {
    let mut z = vec![0.0f32; bucket * model.z_dim];
    let mut y = vec![0.0f32; bucket * model.cond_dim];
    for (i, r) in batch.iter().enumerate() {
        let (rz, cond) = match &r.payload {
            Payload::Latent { z, cond } => (z, cond),
            other => {
                return Err(anyhow!(
                    "{}: validated generate batch got a {} payload \
                     (engine bug)", model.name, other.kind()));
            }
        };
        z[i * model.z_dim..(i + 1) * model.z_dim].copy_from_slice(rz);
        if model.cond_dim > 0 {
            y[i * model.cond_dim..(i + 1) * model.cond_dim]
                .copy_from_slice(cond);
        }
    }
    let zt = Tensor::from_vec(&[bucket, model.z_dim], z);
    let cond = (model.cond_dim > 0)
        .then(|| Tensor::from_vec(&[bucket, model.cond_dim], y));
    Ok((zt, cond))
}

/// One fused forward pass at `bucket` batch size over validated rows.
/// `span`, when present, brackets exactly the backend/plan execution —
/// the `forward` stage boundary (gathers and bucket-split stitching
/// stay outside it).
fn run_forward(model: &Model, plan: Option<&ExecPlan>,
               batch: &[&Request], bucket: usize,
               hnd: &mut WsHandle, mut span: Option<&mut FwdSpan>)
               -> Result<Tensor> {
    let n = batch.len();
    debug_assert!(bucket >= n || matches!(model.backend,
                                          Backend::Pjrt(_)));
    // If even the largest bucket is smaller than the batch, split.
    if bucket < n {
        let mut parts: Vec<Tensor> = Vec::new();
        for chunk in batch.chunks(bucket) {
            parts.push(run_forward(model, plan, chunk, bucket, hnd,
                                   span.as_deref_mut())?);
        }
        // concatenate along batch dim
        let (_, h, w, c) = parts[0].dims4();
        let mut data = Vec::with_capacity(n * h * w * c);
        for (ci, p) in parts.iter().enumerate() {
            let take = (n - ci * bucket).min(bucket);
            data.extend_from_slice(&p.data()[..take * h * w * c]);
        }
        return Ok(Tensor::from_vec(&[n, h, w, c], data));
    }

    match &model.backend {
        Backend::Pjrt(rt) => {
            // Gather latents, zero-padded to the bucket.
            let (zt, cond) = gather_latents(model, batch, bucket)?;
            let name = format!("{}_b{bucket}", model.artifact_prefix);
            let mut inputs: Vec<Tensor> = vec![zt];
            if let Some(c) = cond {
                inputs.push(c);
            }
            // weights are bound resident in the runtime service
            if let Some(s) = span.as_deref_mut() {
                s.enter();
            }
            let outs = rt.run_bound(&name, inputs, &model.name)?;
            if let Some(s) = span {
                s.exit();
            }
            outs.into_iter()
                .next()
                .ok_or_else(|| anyhow!("{name}: no output"))
        }
        Backend::Native(_) | Backend::NativeSeg(_) => {
            // One uniform native path: gather the request payloads into
            // a pooled `(n, in_elems)` batch (latent rows or image rows
            // — the only task-specific step left), then execute the
            // model's load-time-compiled plan. The seg plan ends in the
            // argmax head, so `run_into` yields the client-ready output
            // for both tasks. Native buckets are exact (bucket == n);
            // per-row compute is independent, so outputs stay
            // batch-composition-invariant (DESIGN.md §8/§10). Rows were
            // validated by `validate_row`, so the copies below always
            // fit.
            let plan = plan.expect("native batch without a resident plan");
            let ie = plan.in_elems();
            let mut xb = hnd.checkout(n * ie);
            for (i, r) in batch.iter().enumerate() {
                let row = &mut xb[i * ie..(i + 1) * ie];
                match &r.payload {
                    Payload::Latent { z, cond } => {
                        row[..z.len()].copy_from_slice(z);
                        row[z.len()..].copy_from_slice(cond);
                    }
                    Payload::Image { tensor, .. } => {
                        row.copy_from_slice(tensor.data());
                    }
                }
            }
            let mut out = Tensor::zeros(&plan.out_shape(n));
            if let Some(s) = span.as_deref_mut() {
                s.enter();
            }
            plan.run_into(&xb, n, out.data_mut(), hnd);
            if let Some(s) = span {
                s.exit();
            }
            hnd.checkin(xb);
            Ok(out)
        }
    }
}

/// Best-effort panic-payload message (panics carry `&str` or `String`
/// in practice; anything else is named, not lost).
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Spawn `count` worker threads draining `queue` for `model`.
///
/// Supervision (DESIGN.md §11): each batch executes under
/// `catch_unwind`. A panicking iteration is caught, counted
/// (`Counters::panics`), and every request that had not yet received
/// its outcome is failed with [`ServeError::BatchFailed`] — then the
/// thread goes straight back to draining. An injected panic therefore
/// never shrinks the live worker pool (`tests/fault_stack.rs`).
///
/// A `sink`, when present, observes every batch the workers form and
/// execute (plus per-reply `Response`/`Failed` events from
/// [`execute_batch`]). Each worker thread holds a [`WsHandle`] over the
/// engine's shared `workspace` for its whole lifetime: after the first
/// (warmup) batch of a given shape, every buffer checkout is a hit on
/// the thread's local cache and steady-state serving allocates nothing
/// (`tests/workspace_stack.rs` pins this).
#[allow(clippy::too_many_arguments)]
pub fn spawn_workers(
    model: Arc<Model>,
    queue: Arc<super::queue::BoundedQueue<Request>>,
    cfg: crate::config::EngineConfig,
    counters: Arc<crate::metrics::Counters>,
    model_counters: Arc<crate::metrics::Counters>,
    hist: Arc<crate::metrics::Histogram>,
    sink: Option<Arc<TraceSink>>,
    workspace: Arc<Workspace>,
    obs: Arc<Observability>,
    residency: Option<Arc<Residency>>,
    count: usize,
) -> Vec<std::thread::JoinHandle<()>> {
    // Pin the GEMM microkernel tier before any worker drains a batch:
    // the first `active_isa()` call reads env overrides and runs CPU
    // feature detection behind a `OnceLock`, and that one-time cost must
    // not land inside a latency-measured request.
    let _isa = crate::gemm::active_isa();
    (0..count)
        .map(|widx| {
            let model = model.clone();
            let queue = queue.clone();
            let counters = counters.clone();
            let model_counters = model_counters.clone();
            let hist = hist.clone();
            let sink = sink.clone();
            let workspace = workspace.clone();
            let obs = obs.clone();
            let residency = residency.clone();
            let timeout =
                std::time::Duration::from_micros(cfg.batch_timeout_us);
            let max_batch = cfg.max_batch;
            let continuous = cfg.continuous;
            std::thread::spawn(move || {
                use std::sync::atomic::Ordering::Relaxed;
                let mut hnd = workspace.handle();
                let obs_on = obs.on();
                let task = model.task.index();
                let worker = widx as u32;
                let octx =
                    obs_on.then(|| ObsCtx { obs: &obs, task, worker });
                // continuous-batching spillover (worker-local): rows
                // popped but not seated last batch; always delivered
                // before this worker exits (conservation at shutdown)
                let mut carry: Vec<Request> = Vec::new();
                loop {
                    let on_pop = |r: &mut Request| {
                        if obs_on {
                            r.stamps.popped = Some(Instant::now());
                            obs.flight.record(r.id, Stage::Popped,
                                              worker);
                        }
                    };
                    let batch = if continuous {
                        super::batcher::form_batch(
                            &queue, &mut carry, max_batch, timeout,
                            |r: &Request| r.enqueued,
                            |r: &Request| r.priority.rank(),
                            on_pop)
                    } else {
                        super::batcher::next_batch(
                            &queue, max_batch, timeout,
                            |r: &Request| r.enqueued, on_pop)
                    };
                    let Some(mut batch) = batch else { break };
                    // Weight residency: make this model's plan resident
                    // (evicting LRU peers under the byte budget) before
                    // the batch executes. A refused reload — digest
                    // drift — typed-fails the batch; the worker keeps
                    // draining.
                    let resident = match &residency {
                        Some(res) => match res.ensure(&model) {
                            Ok(h) => h,
                            Err(msg) => {
                                let n = batch.len() as u64;
                                for c in [&counters, &model_counters] {
                                    c.batches.fetch_add(1, Relaxed);
                                    c.batched_requests
                                        .fetch_add(n, Relaxed);
                                    c.failed.fetch_add(n, Relaxed);
                                }
                                eprintln!(
                                    "[worker:{}] residency reload \
                                     failed: {msg}; failing {} \
                                     request(s)", model.name, n);
                                let err = ServeError::BatchFailed(
                                    format!("weight residency: {msg}"));
                                for req in batch.drain(..) {
                                    if !fail_request(req, err.clone(),
                                                     sink.as_deref())
                                    {
                                        for c in [&counters,
                                                  &model_counters] {
                                            c.dropped
                                                .fetch_add(1, Relaxed);
                                        }
                                    }
                                }
                                continue;
                            }
                        },
                        None => None,
                    };
                    if obs_on {
                        // one clock read per batch close, shared by all
                        // members (the batch closes at a single instant)
                        let closed = Instant::now();
                        for r in batch.iter_mut() {
                            r.stamps.batched = Some(closed);
                            obs.flight.record(r.id, Stage::Batched,
                                              worker);
                        }
                    }
                    // id collection only when recording — a plain run
                    // pays just the null-checks (recorder.rs cost model)
                    let ids: Option<Vec<u64>> = sink.as_ref().map(|_| {
                        batch.iter().map(|r| r.id).collect()
                    });
                    if let (Some(s), Some(ids)) = (&sink, &ids) {
                        s.record(EventBody::BatchFormed {
                            ids: ids.clone(),
                        });
                    }
                    let t0 = Instant::now();
                    // Whether execute_batch reached its counter update —
                    // decides who accounts for the requests on panic.
                    let counted = std::cell::Cell::new(false);
                    let res = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| {
                            execute_batch_with(&model, resident,
                                          &mut batch,
                                          sink.as_deref(), &mut hnd,
                                          octx.as_ref(), |o| {
                                counted.set(true);
                                let n = (o.completed + o.failed) as u64;
                                for c in [&counters, &model_counters] {
                                    c.batches.fetch_add(1, Relaxed);
                                    c.batched_requests
                                        .fetch_add(n, Relaxed);
                                    c.completed
                                        .fetch_add(o.completed as u64,
                                                   Relaxed);
                                    c.failed
                                        .fetch_add(o.failed as u64,
                                                   Relaxed);
                                }
                                hist.record(t0.elapsed());
                            })
                        }));
                    match res {
                        Ok(outcome) => {
                            for c in [&counters, &model_counters] {
                                c.dropped.fetch_add(
                                    outcome.dropped as u64, Relaxed);
                            }
                            if let Some(err) = &outcome.error {
                                // requests were answered with
                                // BatchFailed — this is the log line,
                                // not the failure path
                                eprintln!("[worker:{}] batch failed: \
                                           {err}", model.name);
                            }
                            if let (Some(s), Some(ids)) = (&sink, ids) {
                                s.record(EventBody::BatchExecuted {
                                    ids,
                                    bucket: outcome.bucket,
                                    exec_us: t0.elapsed().as_micros()
                                        as u64,
                                });
                            }
                        }
                        Err(p) => {
                            // Supervision: fail what's left, keep
                            // serving. Requests already drained by
                            // execute_batch got their outcome before
                            // the panic.
                            counters.panics.fetch_add(1, Relaxed);
                            model_counters.panics.fetch_add(1, Relaxed);
                            let msg = panic_message(p.as_ref());
                            eprintln!("[worker:{}] panic while executing \
                                       a batch: {msg}; failing {} \
                                       request(s), worker keeps serving",
                                      model.name, batch.len());
                            if !counted.get() {
                                for c in [&counters, &model_counters] {
                                    c.batches.fetch_add(1, Relaxed);
                                    c.batched_requests.fetch_add(
                                        batch.len() as u64, Relaxed);
                                    c.failed.fetch_add(
                                        batch.len() as u64, Relaxed);
                                }
                            }
                            let err = ServeError::BatchFailed(
                                format!("worker panicked: {msg}"));
                            for req in batch.drain(..) {
                                let id = req.id;
                                let enq = req.enqueued;
                                let stamps = req.stamps;
                                if !fail_request(req, err.clone(),
                                                 sink.as_deref())
                                {
                                    for c in [&counters,
                                              &model_counters] {
                                        c.dropped.fetch_add(1, Relaxed);
                                    }
                                }
                                if let Some(o) = &octx {
                                    o.obs.flight.record(
                                        id, Stage::Failed, o.worker);
                                    let popped =
                                        stamps.popped.unwrap_or(enq);
                                    let st = &o.obs.stages;
                                    st.record(
                                        o.task, SpanOutcome::Failed,
                                        STAGE_QUEUE_WAIT,
                                        popped.saturating_duration_since(
                                            enq));
                                    st.record(
                                        o.task, SpanOutcome::Failed,
                                        STAGE_BATCH_FORM,
                                        stamps
                                            .batched
                                            .unwrap_or(popped)
                                            .saturating_duration_since(
                                                popped));
                                }
                            }
                            if obs_on {
                                // the correlating excerpt: recent span
                                // events around the failing request ids
                                eprint!("[worker:{}] {}", model.name,
                                        obs.flight.excerpt(32));
                            }
                        }
                    }
                }
            })
        })
        .collect()
}
