//! Batch execution: fuse a batch of requests into one forward pass (PJRT
//! artifact call, or a native compiled [`crate::plan::ExecPlan`] — one
//! uniform path for every native task), then scatter replies.

use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::Instant;

use crate::replay::event::EventBody;
use crate::replay::recorder::TraceSink;
use crate::tensor::Tensor;
use crate::workspace::{Workspace, WsHandle};

use super::router::{Backend, Model, Payload, Request, Response};

/// Execute one batch on its model and reply to every requester.
///
/// Generate batches are padded with zero latents up to the compiled
/// bucket size (padded outputs are discarded); segment batches run at
/// their exact size. Reply sends ignore disconnected
/// receivers (a client that timed out and dropped its channel).
/// `before_reply` runs after execution but before any reply is sent, so
/// engine counters are consistent the moment a client observes a result.
/// With a recording `sink`, each reply's output checksum is recorded as a
/// `Response` event *before* the send, so the trace is complete even if
/// the client races the recorder to shutdown.
/// `hnd` is the executing worker's workspace handle: native forwards
/// draw padded-batch latents, batch image gathers, activations and GEMM
/// scratch from it, so steady-state batches allocate nothing but the
/// per-request reply tensors (DESIGN.md §9).
pub fn execute_batch(model: &Model, batch: Vec<Request>,
                     sink: Option<&TraceSink>, hnd: &mut WsHandle,
                     before_reply: impl FnOnce(usize)) -> Result<usize> {
    let n = batch.len();
    let bucket = model.bucket_for(n);
    let out = run_forward(model, &batch, bucket, hnd)?;
    before_reply(n);
    let (_, h, w, c) = out.dims4();
    let elems = h * w * c;
    for (i, req) in batch.into_iter().enumerate() {
        let data = out.data()[i * elems..(i + 1) * elems].to_vec();
        let output = Tensor::from_vec(&[1, h, w, c], data);
        let latency = req.enqueued.elapsed();
        if let Some(s) = sink {
            s.record(EventBody::Response {
                id: req.id,
                batch_size: n,
                bucket,
                latency_us: latency.as_micros() as u64,
                checksum: output.checksum(),
            });
        }
        let _ = req.reply.send(Response {
            id: req.id,
            output,
            latency,
            batch_size: n,
            bucket,
        });
    }
    Ok(bucket)
}

/// Destructure a generate request's latent (+ conditioning) payload
/// (the PJRT gather path). Kinds were validated at submit; a mismatch
/// here is an engine bug.
fn latent_parts<'a>(model: &Model, r: &'a Request)
                    -> Result<(&'a [f32], &'a [f32])> {
    match &r.payload {
        Payload::Latent { z, cond } => Ok((z, cond)),
        other => Err(anyhow!("{}: generate batch got a {} payload",
                             model.name, other.kind())),
    }
}

/// Pull the latent (+ conditioning) matrices out of a generate batch,
/// zero-padded to `bucket` rows (the PJRT input form).
fn gather_latents(model: &Model, batch: &[Request], bucket: usize)
                  -> Result<(Tensor, Option<Tensor>)> {
    let mut z = vec![0.0f32; bucket * model.z_dim];
    let mut y = vec![0.0f32; bucket * model.cond_dim];
    for (i, r) in batch.iter().enumerate() {
        let (rz, cond) = latent_parts(model, r)?;
        z[i * model.z_dim..(i + 1) * model.z_dim].copy_from_slice(rz);
        if model.cond_dim > 0 {
            y[i * model.cond_dim..(i + 1) * model.cond_dim]
                .copy_from_slice(cond);
        }
    }
    let zt = Tensor::from_vec(&[bucket, model.z_dim], z);
    let cond = (model.cond_dim > 0)
        .then(|| Tensor::from_vec(&[bucket, model.cond_dim], y));
    Ok((zt, cond))
}

/// One fused forward pass at `bucket` batch size.
fn run_forward(model: &Model, batch: &[Request], bucket: usize,
               hnd: &mut WsHandle) -> Result<Tensor> {
    let n = batch.len();
    debug_assert!(bucket >= n || matches!(model.backend,
                                          Backend::Pjrt(_)));
    // If even the largest bucket is smaller than the batch, split.
    if bucket < n {
        let mut parts: Vec<Tensor> = Vec::new();
        for chunk in batch.chunks(bucket) {
            parts.push(run_forward(model, chunk, bucket, hnd)?);
        }
        // concatenate along batch dim
        let (_, h, w, c) = parts[0].dims4();
        let mut data = Vec::with_capacity(n * h * w * c);
        for (ci, p) in parts.iter().enumerate() {
            let take = (n - ci * bucket).min(bucket);
            data.extend_from_slice(&p.data()[..take * h * w * c]);
        }
        return Ok(Tensor::from_vec(&[n, h, w, c], data));
    }

    match &model.backend {
        Backend::Pjrt(rt) => {
            // Gather latents, zero-padded to the bucket.
            let (zt, cond) = gather_latents(model, batch, bucket)?;
            let name = format!("{}_b{bucket}", model.artifact_prefix);
            let mut inputs: Vec<Tensor> = vec![zt];
            if let Some(c) = cond {
                inputs.push(c);
            }
            // weights are bound resident in the runtime service
            let outs = rt.run_bound(&name, inputs, &model.name)?;
            outs.into_iter()
                .next()
                .ok_or_else(|| anyhow!("{name}: no output"))
        }
        Backend::Native(_) | Backend::NativeSeg(_) => {
            // One uniform native path: gather the request payloads into
            // a pooled `(n, in_elems)` batch (latent rows or image rows
            // — the only task-specific step left), then execute the
            // model's load-time-compiled plan. The seg plan ends in the
            // argmax head, so `run_into` yields the client-ready output
            // for both tasks. Native buckets are exact (bucket == n);
            // per-row compute is independent, so outputs stay
            // batch-composition-invariant (DESIGN.md §8/§10). On a
            // gather error the buffer is checked back in, not dropped —
            // an error path must not shrink the pool.
            let plan = model.plan().expect("native backend without a plan");
            let ie = plan.in_elems();
            let mut xb = hnd.checkout(n * ie);
            let mut gather_err = None;
            for (i, r) in batch.iter().enumerate() {
                let row = &mut xb[i * ie..(i + 1) * ie];
                match &r.payload {
                    Payload::Latent { z, cond }
                        if z.len() + cond.len() == ie =>
                    {
                        row[..z.len()].copy_from_slice(z);
                        row[z.len()..].copy_from_slice(cond);
                    }
                    Payload::Image { tensor, .. }
                        if tensor.len() == ie =>
                    {
                        row.copy_from_slice(tensor.data());
                    }
                    other => {
                        gather_err = Some(anyhow!(
                            "{}: batch got an incompatible {} payload \
                             (plan wants {ie} input elements)",
                            model.name, other.kind()));
                        break;
                    }
                }
            }
            if let Some(e) = gather_err {
                hnd.checkin(xb);
                return Err(e);
            }
            let mut out = Tensor::zeros(&plan.out_shape(n));
            plan.run_into(&xb, n, out.data_mut(), hnd);
            hnd.checkin(xb);
            Ok(out)
        }
    }
}

/// Spawn `count` worker threads draining `queue` for `model`.
///
/// A `sink`, when present, observes every batch the workers form and
/// execute (plus per-reply `Response` events from [`execute_batch`]).
/// Each worker thread holds a [`WsHandle`] over the engine's shared
/// `workspace` for its whole lifetime: after the first (warmup) batch of
/// a given shape, every buffer checkout is a hit on the thread's local
/// cache and steady-state serving allocates nothing
/// (`tests/workspace_stack.rs` pins this).
#[allow(clippy::too_many_arguments)]
pub fn spawn_workers(
    model: Arc<Model>,
    queue: Arc<super::queue::BoundedQueue<Request>>,
    cfg: crate::config::EngineConfig,
    counters: Arc<crate::metrics::Counters>,
    hist: Arc<crate::metrics::Histogram>,
    sink: Option<Arc<TraceSink>>,
    workspace: Arc<Workspace>,
    count: usize,
) -> Vec<std::thread::JoinHandle<()>> {
    (0..count)
        .map(|_| {
            let model = model.clone();
            let queue = queue.clone();
            let counters = counters.clone();
            let hist = hist.clone();
            let sink = sink.clone();
            let workspace = workspace.clone();
            let timeout =
                std::time::Duration::from_micros(cfg.batch_timeout_us);
            let max_batch = cfg.max_batch;
            std::thread::spawn(move || {
                let mut hnd = workspace.handle();
                while let Some(batch) =
                    super::batcher::next_batch(&queue, max_batch, timeout)
                {
                    // id collection only when recording — a plain run
                    // pays just the null-checks (recorder.rs cost model)
                    let ids: Option<Vec<u64>> = sink.as_ref().map(|_| {
                        batch.iter().map(|r| r.id).collect()
                    });
                    if let (Some(s), Some(ids)) = (&sink, &ids) {
                        s.record(EventBody::BatchFormed {
                            ids: ids.clone(),
                        });
                    }
                    let t0 = Instant::now();
                    let res = execute_batch(&model, batch,
                                            sink.as_deref(), &mut hnd,
                                            |n| {
                        use std::sync::atomic::Ordering::Relaxed;
                        counters.batches.fetch_add(1, Relaxed);
                        counters.batched_requests.fetch_add(n as u64,
                                                            Relaxed);
                        counters.completed.fetch_add(n as u64, Relaxed);
                        hist.record(t0.elapsed());
                    });
                    match res {
                        Ok(bucket) => {
                            if let (Some(s), Some(ids)) = (&sink, ids) {
                                s.record(EventBody::BatchExecuted {
                                    ids,
                                    bucket,
                                    exec_us: t0.elapsed().as_micros()
                                        as u64,
                                });
                            }
                        }
                        Err(e) => {
                            // batch dropped; requesters see a closed
                            // channel
                            eprintln!("[worker:{}] batch failed: {e:#}",
                                      model.name);
                        }
                    }
                }
            })
        })
        .collect()
}
