//! Compiled execution plans — one layer IR for every natively served
//! forward path (DESIGN.md §10).
//!
//! Before this module, `gan::Generator` and `seg::SegNet` each
//! hand-rolled their own engine dispatch, activation ping-pong and
//! `forward/forward_ws/forward_into` triplet — exactly the tangle the
//! paper argues against. An [`ExecPlan`] is compiled **once at model
//! load** from the layer configs:
//!
//! * every layer's engine is **resolved** ([`resolve_transpose`] /
//!   [`resolve_dilated`]) — including [`Engine::Auto`], which picks
//!   Baseline vs HUGE² vs the multi-threaded HUGE² engines from a
//!   shape/thread heuristic calibrated at build time;
//! * all prepacked state (HUGE² kernel decomposition,
//!   [`dilated::pack_taps`] panels — both packed when the layer was
//!   built) is **shared by `Arc`**, never re-packed;
//! * every intermediate shape and the workspace high-water mark are
//!   **precomputed**, so steady-state execution is pure slab reuse
//!   through one executor ([`ExecPlan::run_into`]) — the single place
//!   the forward internals of both model families live.
//!
//! The serving coordinator executes plans uniformly (one worker path
//! for generate and segment), and the plan's engine-selection
//! [digest](ExecPlan::engine_digest) rides in the replay trace header
//! so `Engine::Auto` replays deterministically even if the heuristic
//! changes between builds.

use std::sync::atomic::Ordering::Relaxed;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::deconv::dilated::DilatedTaps;
use crate::deconv::huge2::Pattern;
use crate::deconv::segregated::{self, SegPack};
use crate::deconv::{baseline, dilated, huge2, parallel, polyphase_len,
                    DeconvParams, DilatedParams, Engine};
use crate::gan::GenLayer;
use crate::gemm::Tile;
use crate::seg::SegLayer;
use crate::tensor::Tensor;
use crate::workspace::{WsBuf, WsHandle};

// ------------------------------------------------------- Auto heuristic

/// Threads the Auto heuristic assigns to layers heavy enough to shard —
/// the paper's testbed core count (4-core Cortex-A57, DESIGN.md §2).
/// This is the heuristic's *cap*: the resolved count is additionally
/// clamped to the host's [`std::thread::available_parallelism`] (see
/// [`resolve_auto_threads`]) so 2-core edge targets never oversubscribe.
pub const AUTO_THREADS: usize = 4;

/// Host parallelism cap for the Auto heuristic, resolved once per
/// process (`available_parallelism` can syscall on some platforms).
pub(crate) fn host_threads() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Threads the Auto heuristic resolves for a layer with `eff_macs`
/// effective MACs on a host with `cap` available cores: 1 below the MT
/// cutoff, else [`AUTO_THREADS`] clamped to the host — never above the
/// heuristic's own cap, never above `cap`. Pure (explicit `cap`) so
/// tests can pin both clamp directions without faking the host.
pub fn resolve_auto_threads(eff_macs: u64, cap: usize) -> usize {
    if eff_macs >= AUTO_MT_MIN_MACS {
        AUTO_THREADS.min(cap.max(1))
    } else {
        1
    }
}

/// Per-image effective MACs above which the multi-threaded HUGE²
/// engines pay for their shard spawn/join (calibrated on the
/// `ablations` bench's multicore-scaling phase: below ~8 M MACs the
/// scoped-thread overhead eats the win).
pub const AUTO_MT_MIN_MACS: u64 = 8 << 20;

/// Per-image effective MACs below which a dilation-1 dilated conv runs
/// faster as the baseline's one fused im2col GEMM than as `R·S` small
/// per-row tap GEMMs (at dilation 1 untangling skips no zeros, so the
/// fused GEMM's better blocking wins on small layers).
pub const AUTO_FUSED_MAX_MACS: u64 = 1 << 16;

/// Resolve a transposed-conv layer's engine + thread count. Concrete
/// requests pass through (`threads_hint` floors the thread count for
/// HUGE²; Baseline is always single-threaded — its MT variant has no
/// slice-level core). `Auto`: stride 1 has no zeros to skip, so the
/// baseline's single fused GEMM wins; otherwise HUGE², multi-threaded
/// when the layer is heavy enough.
#[allow(clippy::too_many_arguments)]
pub fn resolve_transpose(requested: Engine, h: usize, w: usize,
                         c_in: usize, c_out: usize, k: usize,
                         p: &DeconvParams, threads_hint: usize)
                         -> (Engine, usize) {
    match requested {
        Engine::Baseline => (Engine::Baseline, 1),
        Engine::Huge2 => (Engine::Huge2, threads_hint.max(1)),
        // explicit-only: Auto never picks Segregated, so existing plan
        // digests (and the traces that embed them) stay valid
        Engine::Segregated => (Engine::Segregated, threads_hint.max(1)),
        Engine::Auto => {
            if p.stride == 1 {
                return (Engine::Baseline, 1);
            }
            let (_, eff) = huge2::mac_counts(h, w, c_in, c_out, k, k, p);
            let auto = resolve_auto_threads(eff, host_threads());
            (Engine::Huge2, threads_hint.max(1).max(auto))
        }
    }
}

/// Resolve a dilated-conv layer's engine + thread count (the dilated
/// twin of [`resolve_transpose`]). `Auto`: dilation > 1 always favors
/// untangling (the baseline pays `d²` dense MACs over the inflated
/// kernel); at dilation 1 small layers keep the baseline's fused GEMM.
#[allow(clippy::too_many_arguments)]
pub fn resolve_dilated(requested: Engine, h: usize, w: usize, c_in: usize,
                       c_out: usize, k: usize, p: &DilatedParams,
                       threads_hint: usize) -> (Engine, usize) {
    match requested {
        Engine::Baseline => (Engine::Baseline, 1),
        Engine::Huge2 => (Engine::Huge2, threads_hint.max(1)),
        // dilated convs have no inserted zeros to segregate — the
        // request falls through to the untangled engine
        Engine::Segregated => (Engine::Huge2, threads_hint.max(1)),
        Engine::Auto => {
            let (_, eff) = dilated::mac_counts(h, w, c_in, c_out, k, k, p);
            if p.dilation == 1 && eff < AUTO_FUSED_MAX_MACS {
                return (Engine::Baseline, 1);
            }
            let auto = resolve_auto_threads(eff, host_threads());
            (Engine::Huge2, threads_hint.max(1).max(auto))
        }
    }
}

// ------------------------------------------------------ shared dispatch

/// The one transposed-conv dispatch site: slice-level forward through a
/// **concrete** (already resolved) engine. Both the plan executor and
/// [`GenLayer::forward`] route here, so engine dispatch exists in
/// exactly one place.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_transpose_op(xd: &[f32], b: usize, h: usize, w: usize,
                               c_in: usize, kernel: &Tensor,
                               patterns: &[Pattern], k: usize,
                               p: &DeconvParams, engine: Engine,
                               threads: usize, seg: Option<&SegPack>,
                               out: &mut [f32], hnd: &mut WsHandle) {
    // The fused per-pattern panels: compiled plans carry them
    // (`PlanOp::TransposeConv::seg`, packed at compile); the legacy
    // per-call path passes `None` and packs transiently here.
    let seg_transient;
    let seg = match (engine, seg) {
        (Engine::Segregated, Some(sp)) => Some(sp),
        (Engine::Segregated, None) => {
            seg_transient = SegPack::from_patterns(patterns);
            Some(&seg_transient)
        }
        _ => None,
    };
    match engine {
        Engine::Baseline => baseline::transpose_into(
            xd, b, h, w, c_in, kernel, p, out, hnd),
        Engine::Huge2 if threads > 1 => parallel::transpose_mt_into(
            xd, b, h, w, c_in, patterns, k, k, p, threads, out,
            hnd.workspace()),
        Engine::Huge2 => huge2::transpose_into(
            xd, b, h, w, c_in, patterns, k, k, p, out, hnd),
        Engine::Segregated if threads > 1 => segregated::transpose_mt_into(
            xd, b, h, w, c_in, patterns, seg.unwrap(), k, k, p, threads,
            out, hnd.workspace()),
        Engine::Segregated => segregated::transpose_into(
            xd, b, h, w, c_in, patterns, seg.unwrap(), k, k, p, out, hnd),
        Engine::Auto => unreachable!("Auto must be resolved before dispatch"),
    }
}

/// The one dilated-conv dispatch site (see [`run_transpose_op`]); both
/// the plan executor and [`SegLayer::forward`] route here.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_dilated_op(xd: &[f32], b: usize, h: usize, w: usize,
                             c_in: usize, kernel: &Tensor,
                             taps: &DilatedTaps, p: &DilatedParams,
                             engine: Engine, threads: usize,
                             out: &mut [f32], hnd: &mut WsHandle) {
    match engine {
        Engine::Baseline => baseline::conv2d_dilated_into(
            xd, b, h, w, c_in, kernel, p, out, hnd),
        Engine::Huge2 if threads > 1 => parallel::dilated_mt_into(
            xd, b, h, w, c_in, taps, p, threads, out, hnd.workspace()),
        Engine::Huge2 => dilated::dilated_into(
            xd, b, h, w, c_in, taps, p, out, hnd),
        Engine::Segregated => unreachable!(
            "resolve_dilated maps Segregated to Huge2"),
        Engine::Auto => unreachable!("Auto must be resolved before dispatch"),
    }
}

// ----------------------------------------------------------------- IR

/// Elementwise activation op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Relu,
    Tanh,
}

impl Act {
    fn name(&self) -> &'static str {
        match self {
            Act::Relu => "relu",
            Act::Tanh => "tanh",
        }
    }

    fn apply(&self, buf: &mut [f32]) {
        match self {
            Act::Relu => crate::tensor::relu_inplace(buf),
            Act::Tanh => crate::tensor::tanh_inplace(buf),
        }
    }
}

/// How a conv step joins the dataflow: sequential, or as a branch of a
/// parallel pyramid (ASPP) whose branches all read the saved group
/// input and sum into one accumulator in IR order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fan {
    /// Reads the current activation, produces the next one.
    Seq,
    /// First pyramid branch: saves the current activation as the group
    /// input and produces the accumulator.
    BranchFirst,
    /// Later pyramid branch: reads the saved group input, sums into the
    /// accumulator.
    BranchAdd,
}

/// Output head op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Head {
    /// Per-pixel class argmax: logits `(b,h,w,K)` → mask `(b,h,w,1)`
    /// (ties break low — deterministic, replay-checksummable).
    ArgmaxMask { classes: usize },
}

/// One IR op, carrying the prepacked state it executes with (shared via
/// `Arc` from the owning model layer — compiled plans never re-pack).
#[derive(Debug, Clone)]
pub enum PlanOp {
    /// Dense latent projection `(b, in_dim) @ w → (b, out_dim)`.
    Project {
        w: Arc<Tensor>,
        in_dim: usize,
        out_dim: usize,
    },
    /// Stride-`s` transposed convolution (GAN upsampling family).
    TransposeConv {
        kernel: Arc<Tensor>,
        patterns: Arc<Vec<Pattern>>,
        /// Fused per-pattern panels for the kernel-segregated engine —
        /// packed at plan compile (only when the step resolved to
        /// [`Engine::Segregated`]), `Arc`-shared with plan clones.
        seg: Option<Arc<SegPack>>,
        k: usize,
        params: DeconvParams,
        h: usize,
        c_in: usize,
        c_out: usize,
    },
    /// Dilated (atrous) convolution (segmentation family).
    DilatedConv {
        kernel: Arc<Tensor>,
        taps: Arc<DilatedTaps>,
        params: DilatedParams,
        h: usize,
        c_in: usize,
        c_out: usize,
        fan: Fan,
    },
    /// In-place elementwise activation on the current buffer.
    Activation(Act),
    /// Output head.
    Head(Head),
}

impl PlanOp {
    /// Wire/table tag of the op kind.
    pub fn kind(&self) -> &'static str {
        match self {
            PlanOp::Project { .. } => "project",
            PlanOp::TransposeConv { .. } => "transpose-conv",
            PlanOp::DilatedConv { fan: Fan::Seq, .. } => "dilated-conv",
            PlanOp::DilatedConv { .. } => "dilated-conv[aspp]",
            PlanOp::Activation(_) => "activation",
            PlanOp::Head(_) => "head",
        }
    }

    /// Does this op produce a new activation buffer (vs mutating or
    /// accumulating into an existing one)?
    fn is_producer(&self) -> bool {
        !matches!(self,
                  PlanOp::Activation(_)
                  | PlanOp::DilatedConv { fan: Fan::BranchAdd, .. })
    }
}

/// One compiled step: the op plus everything resolved at compile time —
/// concrete engine, thread count, per-image output geometry, prepacked
/// bytes. What `huge2 plan` prints a row per.
#[derive(Debug, Clone)]
pub struct PlanStep {
    /// Layer/op name (config name, or `proj`/`relu`/`tanh`/`argmax`).
    pub name: String,
    pub op: PlanOp,
    /// Resolved concrete engine (`None` for activations/heads).
    pub engine: Option<Engine>,
    pub threads: usize,
    /// Tuned GEMM cache-blocking override for the Project step
    /// (`None` = compile-time default). Only ever set by
    /// [`ExecPlan::with_tuning`]; a non-default tile regroups K-panel
    /// partial sums, so it folds into the digest like the FMA
    /// numerics term (DESIGN.md §15).
    pub tile: Option<Tile>,
    /// Per-image output shape `[h, w, c]`.
    pub out_shape: [usize; 3],
    /// Per-image output element count (`h·w·c`).
    pub out_elems: usize,
    /// Bytes of GEMM-packed panels this step reuses (paid at model
    /// load, zero per inference).
    pub prepacked_bytes: usize,
}

// ------------------------------------------------------------ profiler

/// EWMA smoothing factor for per-step wall times (see
/// [`StepProfile`]). 0.2 ≈ a ~5-sample horizon: reactive enough for
/// the serving profile table, smooth enough to rank layers stably.
const PROFILE_EWMA_ALPHA: f32 = 0.2;

/// Lock-free accumulator for one plan step's observed cost. All fields
/// are atomics so concurrent workers executing the same (cloned,
/// profile-sharing) plan fold into one profile without coordination.
#[derive(Debug)]
struct StepProfile {
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
    /// EWMA of the step's wall µs, stored as `f32` bits (CAS loop —
    /// last-writer-wins under contention, which is fine for telemetry).
    ewma_us: AtomicU32,
    /// Peak workspace class bytes checked out during one execution of
    /// this step (through the executing handle; MT shard-internal
    /// checkouts route through the shared pool and are not attributed).
    ws_bytes: AtomicU64,
}

impl StepProfile {
    fn new() -> Self {
        StepProfile {
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
            ewma_us: AtomicU32::new(0f32.to_bits()),
            ws_bytes: AtomicU64::new(0),
        }
    }

    fn record(&self, us: u64, ws_bytes: u64) {
        let n = self.count.fetch_add(1, Relaxed);
        self.sum_us.fetch_add(us, Relaxed);
        self.max_us.fetch_max(us, Relaxed);
        self.ws_bytes.fetch_max(ws_bytes, Relaxed);
        let sample = us as f32;
        let mut cur = self.ewma_us.load(Relaxed);
        loop {
            let prev = f32::from_bits(cur);
            let next = if n == 0 {
                sample // first sample seeds the average
            } else {
                prev + PROFILE_EWMA_ALPHA * (sample - prev)
            };
            match self.ewma_us.compare_exchange_weak(
                cur, next.to_bits(), Relaxed, Relaxed) {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
    }
}

/// Point-in-time copy of one step's profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepProfileSnapshot {
    pub count: u64,
    pub mean_us: f64,
    pub ewma_us: f32,
    pub max_us: u64,
    /// Peak workspace class bytes one execution of the step checked
    /// out through the executing handle.
    pub ws_bytes: u64,
}

/// Per-plan, per-step observed-cost profile (DESIGN.md §12). Off by
/// default; [`PlanProfile::set_enabled`] arms the `run_into` hooks.
/// Shared by `Arc` across plan clones, so enabling profiling on a
/// model's stored plan also profiles the serving workers executing
/// clones of it.
#[derive(Debug)]
pub struct PlanProfile {
    enabled: AtomicBool,
    steps: Vec<StepProfile>,
}

impl PlanProfile {
    fn new(n_steps: usize) -> Self {
        let mut steps = Vec::with_capacity(n_steps);
        steps.resize_with(n_steps, StepProfile::new);
        PlanProfile { enabled: AtomicBool::new(false), steps }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Relaxed);
    }

    fn record(&self, step: usize, us: u64, ws_bytes: u64) {
        self.steps[step].record(us, ws_bytes);
    }

    /// Snapshot of step `i`'s accumulated profile.
    pub fn step(&self, i: usize) -> StepProfileSnapshot {
        let s = &self.steps[i];
        let count = s.count.load(Relaxed);
        let sum = s.sum_us.load(Relaxed);
        StepProfileSnapshot {
            count,
            mean_us: if count == 0 { 0.0 } else {
                sum as f64 / count as f64
            },
            ewma_us: f32::from_bits(s.ewma_us.load(Relaxed)),
            max_us: s.max_us.load(Relaxed),
            ws_bytes: s.ws_bytes.load(Relaxed),
        }
    }

    /// Samples recorded so far (any step — steps record in lockstep,
    /// so step 0's count is the number of profiled plan executions).
    pub fn runs(&self) -> u64 {
        self.steps.first().map_or(0, |s| s.count.load(Relaxed))
    }

    /// Zero every step's accumulators (profiling stays armed/disarmed
    /// as it was). Only meaningful while no worker is mid-run.
    pub fn reset(&self) {
        for s in &self.steps {
            s.count.store(0, Relaxed);
            s.sum_us.store(0, Relaxed);
            s.max_us.store(0, Relaxed);
            s.ewma_us.store(0f32.to_bits(), Relaxed);
            s.ws_bytes.store(0, Relaxed);
        }
    }
}

// ------------------------------------------------------------- tuning

/// One tuned per-step choice the autotuner measured as the argmin
/// (see [`crate::tune`]). Applied by [`ExecPlan::with_tuning`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepSelection {
    /// Step index in the compiled plan this selection targets.
    pub step: usize,
    /// Concrete engine for a conv step (`None` = leave as compiled;
    /// `Auto` is not a valid tuned choice and is ignored).
    pub engine: Option<Engine>,
    /// Thread count for a conv step (Baseline forces 1).
    pub threads: usize,
    /// GEMM cache-blocking for a Project step (`None`/default = leave
    /// the compile-time blocking).
    pub tile: Option<Tile>,
}

/// The autotuner's full selection set for one plan.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PlanTuning {
    pub selections: Vec<StepSelection>,
}

/// A compiled forward plan: the unified executable form of a
/// [`crate::gan::Generator`] or [`crate::seg::SegNet`] (plus, for
/// serving, an output head). See the module docs and DESIGN.md §10.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    /// What compile was asked for: `Some(engine)` = one engine applied
    /// to every layer (possibly `Auto`); `None` = per-layer config
    /// engines. Model forwards use this to route matching calls to the
    /// stored plan instead of compiling a transient one.
    requested: Option<Engine>,
    steps: Vec<PlanStep>,
    /// Per-request input element count.
    in_elems: usize,
    /// FNV-1a over every resolved (name, op, engine, threads, shape) —
    /// precomputed; recorded in replay trace headers.
    digest: u64,
    /// Observed per-step costs, shared across plan clones (a model's
    /// stored plan and the worker-side clones fold into one profile).
    profile: Arc<PlanProfile>,
}

impl ExecPlan {
    // ------------------------------------------------------- compile

    /// Compile a fresh plan for a built generator with one engine
    /// applied to every layer (`Auto` included). Cheap: the prepacked
    /// state is `Arc`-shared from the generator's layers, never
    /// re-packed — the serving plan the generator already stores is
    /// [`crate::gan::Generator::plan`].
    pub fn for_generator(gen: &crate::gan::Generator, engine: Engine)
                         -> ExecPlan {
        ExecPlan::compile_gan(&gen.proj, &gen.layers, engine)
    }

    /// Compile a fresh logits plan for a built seg net. `over` = one
    /// engine for every layer; `None` honors the per-layer config
    /// engines (resolving `Auto`). The net's stored serving plan is
    /// [`crate::seg::SegNet::plan`]; append
    /// [`ExecPlan::with_argmax_head`] for the mask-producing form.
    pub fn for_segnet(net: &crate::seg::SegNet, over: Option<Engine>)
                      -> ExecPlan {
        ExecPlan::compile_seg(&net.trunk, &net.aspp, &net.head, over)
    }

    /// Compile a generator-shaped plan: dense projection → relu →
    /// transposed-conv stack (relu between layers, tanh head).
    pub(crate) fn compile_gan(proj: &Arc<Tensor>, layers: &[GenLayer],
                              engine: Engine) -> ExecPlan {
        let (in_dim, hid) = proj.dims2();
        let first = &layers[0].cfg;
        debug_assert_eq!(hid, first.h * first.h * first.c_in);
        let mut steps = Vec::with_capacity(2 + 2 * layers.len());
        push_step(&mut steps, "proj",
                  PlanOp::Project { w: proj.clone(), in_dim, out_dim: hid },
                  None, 1, [first.h, first.h, first.c_in], 0);
        push_act(&mut steps, Act::Relu);
        let n = layers.len();
        for (i, l) in layers.iter().enumerate() {
            let cfg = &l.cfg;
            let p = cfg.deconv_params();
            let (eng, threads) = resolve_transpose(
                engine, cfg.h, cfg.h, cfg.c_in, cfg.c_out, cfg.k, &p, 1);
            // fused panels exist only when the step runs segregated —
            // every other resolution keeps the per-tap panels
            let seg = (eng == Engine::Segregated)
                .then(|| Arc::new(SegPack::from_patterns(&l.patterns)));
            let prepacked = match &seg {
                Some(sp) => sp.bytes(),
                None => l.patterns.iter()
                    .flat_map(|pt| pt.packed.iter())
                    .map(|pb| pb.bytes())
                    .sum(),
            };
            push_step(&mut steps, cfg.name,
                      PlanOp::TransposeConv {
                          kernel: l.kernel.clone(),
                          patterns: l.patterns.clone(),
                          seg,
                          k: cfg.k,
                          params: p,
                          h: cfg.h,
                          c_in: cfg.c_in,
                          c_out: cfg.c_out,
                      },
                      Some(eng), threads,
                      [cfg.h_out(), cfg.h_out(), cfg.c_out], prepacked);
            push_act(&mut steps,
                     if i == n - 1 { Act::Tanh } else { Act::Relu });
        }
        ExecPlan::new(Some(engine), in_dim, steps)
    }

    /// Compile a segnet-shaped plan: dilated trunk (relu each) →
    /// parallel atrous pyramid (branches summed, relu) → 1×1 head.
    /// `over` = engine applied to every layer; `None` honors each
    /// layer's configured engine (resolving any `Auto`). The plan ends
    /// at the logits — serving appends [`ExecPlan::with_argmax_head`].
    pub(crate) fn compile_seg(trunk: &[SegLayer], aspp: &[SegLayer],
                              head: &SegLayer, over: Option<Engine>)
                              -> ExecPlan {
        let first = &trunk[0].cfg;
        let in_elems = first.h * first.h * first.c_in;
        let mut steps = Vec::new();
        let dilated_step = |steps: &mut Vec<PlanStep>, l: &SegLayer,
                            fan: Fan| {
            let cfg = &l.cfg;
            let (eng, threads) = resolve_dilated(
                over.unwrap_or(cfg.engine), cfg.h, cfg.h, cfg.c_in,
                cfg.c_out, cfg.k, &cfg.params, cfg.threads);
            push_step(steps, cfg.name,
                      PlanOp::DilatedConv {
                          kernel: l.kernel.clone(),
                          taps: l.taps.clone(),
                          params: cfg.params,
                          h: cfg.h,
                          c_in: cfg.c_in,
                          c_out: cfg.c_out,
                          fan,
                      },
                      Some(eng), threads,
                      [cfg.h_out(), cfg.h_out(), cfg.c_out],
                      l.taps.packed_bytes());
        };
        for l in trunk {
            dilated_step(&mut steps, l, Fan::Seq);
            push_act(&mut steps, Act::Relu);
        }
        for (i, l) in aspp.iter().enumerate() {
            // branches are summed elementwise into one accumulator, so
            // every branch must produce the first branch's shape (the
            // check the legacy forward made per call now runs once, at
            // compile)
            assert_eq!(
                (l.cfg.h_out(), l.cfg.c_out),
                (aspp[0].cfg.h_out(), aspp[0].cfg.c_out),
                "ASPP branch shape mismatch: {}", l.cfg.name);
            let fan = if i == 0 { Fan::BranchFirst } else { Fan::BranchAdd };
            dilated_step(&mut steps, l, fan);
        }
        push_act(&mut steps, Act::Relu);
        dilated_step(&mut steps, head, Fan::Seq);
        ExecPlan::new(over, in_elems, steps)
    }

    /// This plan with every HUGE²/segregated conv step's thread count
    /// forced to `threads` (Baseline steps stay single-threaded). The
    /// MT engines are bit-identical across thread counts (DESIGN.md
    /// §8), so this is a pure throughput knob for deployments with a
    /// different core budget — and the lever the plan-vs-legacy
    /// bit-identity grid sweeps.
    pub fn with_threads(&self, threads: usize) -> ExecPlan {
        let mut steps = self.steps.clone();
        for st in &mut steps {
            if matches!(st.engine,
                        Some(Engine::Huge2) | Some(Engine::Segregated)) {
                st.threads = threads.max(1);
            }
        }
        ExecPlan::new(self.requested, self.in_elems, steps)
    }

    /// This plan with the autotuner's per-step selections applied —
    /// the measured-argmin twin of [`ExecPlan::with_threads`]
    /// (DESIGN.md §15). Engine flips re-pack exactly the state the new
    /// engine needs (fused [`SegPack`] panels appear when a step turns
    /// segregated, drop when it turns away); thread counts follow the
    /// engine's rules (Baseline is always single-threaded); Project
    /// steps take the tuned GEMM tile (`None`/default = untouched).
    /// The rebuilt plan recomputes its digest, so a tuned plan whose
    /// selections differ from the heuristic's diverges loudly at the
    /// replay digest gate — and one whose selections all match is
    /// digest-identical to the heuristic plan.
    pub fn with_tuning(&self, tuning: &PlanTuning) -> ExecPlan {
        let mut steps = self.steps.clone();
        for sel in &tuning.selections {
            let st = match steps.get_mut(sel.step) {
                Some(st) => st,
                None => continue, // stale selection index: ignore
            };
            match &mut st.op {
                PlanOp::TransposeConv { patterns, seg, .. } => {
                    let eng = match sel.engine {
                        Some(Engine::Auto) | None => continue,
                        Some(e) => e,
                    };
                    st.engine = Some(eng);
                    st.threads = if eng == Engine::Baseline {
                        1
                    } else {
                        sel.threads.max(1)
                    };
                    if eng == Engine::Segregated {
                        if seg.is_none() {
                            *seg = Some(Arc::new(
                                SegPack::from_patterns(patterns)));
                        }
                    } else {
                        *seg = None;
                    }
                    st.prepacked_bytes = match seg {
                        Some(sp) => sp.bytes(),
                        None => patterns.iter()
                            .flat_map(|pt| pt.packed.iter())
                            .map(|pb| pb.bytes())
                            .sum(),
                    };
                }
                PlanOp::DilatedConv { .. } => {
                    let eng = match sel.engine {
                        Some(Engine::Auto) | None => continue,
                        // no zeros to segregate on the dilated path
                        // (mirrors `resolve_dilated`)
                        Some(Engine::Segregated) => Engine::Huge2,
                        Some(e) => e,
                    };
                    st.engine = Some(eng);
                    st.threads = if eng == Engine::Baseline {
                        1
                    } else {
                        sel.threads.max(1)
                    };
                }
                PlanOp::Project { .. } => {
                    st.tile = sel.tile.map(Tile::clamped)
                        .filter(|t| !t.is_default());
                }
                PlanOp::Activation(_) | PlanOp::Head(_) => {}
            }
        }
        ExecPlan::new(self.requested, self.in_elems, steps)
    }

    /// This plan plus an output head — the serving form (e.g. the seg
    /// model's per-pixel argmax, so the worker's `run_into` yields the
    /// client-ready mask directly).
    pub fn with_argmax_head(&self, classes: usize) -> ExecPlan {
        let last = self.steps.last().expect("plan has steps");
        let [h, w, k] = last.out_shape;
        assert_eq!(k, classes, "head classes must match the logits");
        let mut steps = self.steps.clone();
        push_step(&mut steps, "argmax",
                  PlanOp::Head(Head::ArgmaxMask { classes }), None, 1,
                  [h, w, 1], 0);
        ExecPlan::new(self.requested, self.in_elems, steps)
    }

    fn new(requested: Option<Engine>, in_elems: usize,
           steps: Vec<PlanStep>) -> ExecPlan {
        assert!(steps.iter().any(|s| s.op.is_producer()),
                "a plan needs at least one producing op");
        let digest = digest_steps(requested, in_elems, &steps);
        let profile = Arc::new(PlanProfile::new(steps.len()));
        ExecPlan { requested, steps, in_elems, digest, profile }
    }

    // ----------------------------------------------------- introspect

    pub fn requested(&self) -> Option<Engine> {
        self.requested
    }

    /// True when every compiled compute step resolved to the concrete
    /// engine `e` — executing this plan is then bit-identical to one
    /// compiled with `e` applied everywhere (thread counts may differ;
    /// the MT engines are bit-identical across thread counts, §8).
    /// Model forwards use this to route explicit-engine calls to the
    /// stored plan instead of compiling a transient one, keeping the
    /// steady-state allocation-free (DESIGN.md §9).
    pub fn resolves_to(&self, e: Engine) -> bool {
        e != Engine::Auto
            && self.steps.iter()
                .all(|s| s.engine.is_none() || s.engine == Some(e))
    }

    pub fn steps(&self) -> &[PlanStep] {
        &self.steps
    }

    /// Per-request input element count (latent width, or `h·w·c` of one
    /// image).
    pub fn in_elems(&self) -> usize {
        self.in_elems
    }

    /// Per-image output element count.
    pub fn out_elems(&self) -> usize {
        self.steps.last().unwrap().out_elems
    }

    /// Output tensor shape for batch `b`.
    pub fn out_shape(&self, b: usize) -> Vec<usize> {
        let [h, w, c] = self.steps.last().unwrap().out_shape;
        vec![b, h, w, c]
    }

    /// Total bytes of prepacked GEMM panels the plan reuses (paid once
    /// at model load).
    pub fn prepacked_bytes(&self) -> usize {
        self.steps.iter().map(|s| s.prepacked_bytes).sum()
    }

    /// FNV-1a digest of every resolved engine choice (layer name, op,
    /// engine, threads, shape). Recorded in replay trace headers so a
    /// replay proves it runs the *same* selections as the recording —
    /// the guard that keeps `Engine::Auto` deterministic across
    /// heuristic changes (DESIGN.md §10).
    pub fn engine_digest(&self) -> u64 {
        self.digest
    }

    /// The plan's observed-cost profile (shared across clones; see
    /// [`PlanProfile`]).
    pub fn profile(&self) -> &PlanProfile {
        &self.profile
    }

    /// Persisted form of the profile, keyed by the engine-selection
    /// digest so a future autotuner can match observed costs back to
    /// the exact selections that produced them (ROADMAP item 4). One
    /// header line, then one whitespace-separated line per step:
    ///
    /// ```text
    /// # huge2 plan profile v1 digest=<016x> steps=<n> in_elems=<n>
    /// <idx> <name> <kind> <engine|-> <threads> <count> <ewma_us> \
    ///     <mean_us> <max_us> <ws_bytes>
    /// ```
    pub fn profile_report(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "# huge2 plan profile v1 digest={:016x} steps={} in_elems={}",
            self.digest,
            self.steps.len(),
            self.in_elems
        );
        for (i, st) in self.steps.iter().enumerate() {
            let p = self.profile.step(i);
            let _ = writeln!(
                out,
                "{} {} {} {} {} {} {:.1} {:.1} {} {}",
                i,
                st.name,
                st.op.kind(),
                st.engine.map(|e| e.name()).unwrap_or("-"),
                st.threads,
                p.count,
                p.ewma_us,
                p.mean_us,
                p.max_us,
                p.ws_bytes
            );
        }
        out
    }

    /// Workspace high-water mark for batch `b`: the peak pooled
    /// elements the executor holds live at once (activation slabs plus
    /// per-step engine scratch), walked over the same schedule
    /// [`ExecPlan::run_into`] executes. Size classes round each slab up
    /// to a power of two, so the pool's steady footprint is this
    /// figure's class-rounded ceiling (DESIGN.md §9/§10).
    pub fn high_water_elems(&self, b: usize) -> usize {
        let last_prod = self.last_producer();
        let mut peak = 0usize;
        let mut cur = 0usize; // live current-activation elems
        let mut saved = 0usize; // live ASPP group-input elems
        for (i, st) in self.steps.iter().enumerate() {
            let scratch = step_scratch_elems(st, b);
            match &st.op {
                PlanOp::Activation(_) => {}
                PlanOp::DilatedConv { fan: Fan::BranchFirst, .. } => {
                    saved = cur;
                    let dst = if i == last_prod { 0 } else {
                        b * st.out_elems
                    };
                    peak = peak.max(saved + dst + scratch);
                    cur = dst;
                }
                PlanOp::DilatedConv { fan: Fan::BranchAdd, .. } => {
                    let scr = b * st.out_elems;
                    peak = peak.max(saved + cur + scr + scratch);
                }
                _ => {
                    // sequential producer: old cur + new dst live at once
                    let dst = if i == last_prod { 0 } else {
                        b * st.out_elems
                    };
                    peak = peak.max(saved + cur + dst + scratch);
                    cur = dst;
                    saved = 0;
                }
            }
        }
        peak
    }

    fn last_producer(&self) -> usize {
        self.steps.iter().rposition(|s| s.op.is_producer())
            .expect("plan has a producer")
    }

    // ------------------------------------------------------- execute

    /// Tensor-level convenience over [`ExecPlan::run_into`] (the output
    /// tensor is client-owned — the one allocation a plan run makes).
    pub fn run(&self, x: &Tensor, hnd: &mut WsHandle) -> Tensor {
        let b = x.len() / self.in_elems;
        let mut out = Tensor::zeros(&self.out_shape(b));
        self.run_into(x.data(), b, out.data_mut(), hnd);
        out
    }

    /// Execute the plan: `xd` is the `(b, in_elems)` input, `out` the
    /// `(b, out_elems)` destination (fully overwritten). Every
    /// intermediate draws from `hnd` at its precompiled size; after a
    /// warmup batch of a given size, execution is pure slab reuse
    /// (`tests/workspace_stack.rs` pins this).
    ///
    /// This is **the** forward executor: `Generator::forward*`,
    /// `SegNet::forward*` and the coordinator workers are all thin
    /// wrappers over it.
    pub fn run_into(&self, xd: &[f32], b: usize, out: &mut [f32],
                    hnd: &mut WsHandle) {
        assert_eq!(xd.len(), b * self.in_elems, "plan input size");
        assert_eq!(out.len(), b * self.out_elems(), "plan output size");
        let last_prod = self.last_producer();

        // Current activation: the caller's input until the first
        // producer runs, then a pooled slab, then `out` after the last
        // producer. `saved` holds the pyramid group input while ASPP
        // branches accumulate.
        enum Cursor {
            Input,
            Buf(WsBuf),
            Out,
        }
        let mut cursor = Cursor::Input;
        let mut saved: Option<Cursor> = None;
        // one branch per run when profiling is off; when on, each step
        // pays one Instant read + one handle-local byte read per side
        let profiling = self.profile.enabled();

        for (i, st) in self.steps.iter().enumerate() {
            let prof_t0 = profiling
                .then(|| (Instant::now(), hnd.checked_out_bytes()));
            // a finished pyramid group releases its saved input: any op
            // other than a later branch (or an in-place activation on
            // the accumulator) means the group is over
            let keeps_saved = matches!(
                &st.op,
                PlanOp::Activation(_)
                | PlanOp::DilatedConv { fan: Fan::BranchAdd, .. });
            if !keeps_saved {
                if let Some(Cursor::Buf(old)) = saved.take() {
                    hnd.checkin(old);
                }
            }
            match &st.op {
                PlanOp::Activation(a) => match &mut cursor {
                    Cursor::Input => {
                        unreachable!("activation cannot lead a plan")
                    }
                    Cursor::Buf(buf) => a.apply(buf),
                    Cursor::Out => a.apply(out),
                },
                PlanOp::DilatedConv { kernel, taps, params, h, c_in,
                                      fan: Fan::BranchAdd, .. } => {
                    let mut scratch = hnd.checkout(b * st.out_elems);
                    {
                        let src: &[f32] = match saved.as_ref()
                            .expect("BranchAdd outside a pyramid group")
                        {
                            Cursor::Input => xd,
                            Cursor::Buf(buf) => buf,
                            Cursor::Out => unreachable!(),
                        };
                        run_dilated_op(src, b, *h, *h, *c_in, kernel, taps,
                                       params, st.engine.unwrap(),
                                       st.threads, &mut scratch, hnd);
                    }
                    let acc: &mut [f32] = match &mut cursor {
                        Cursor::Buf(buf) => buf,
                        Cursor::Out => out,
                        Cursor::Input => unreachable!(),
                    };
                    for (a, y) in acc.iter_mut().zip(scratch.iter()) {
                        *a += *y;
                    }
                    hnd.checkin(scratch);
                }
                op => {
                    // sequential producer (Project / conv / head) or
                    // the first pyramid branch
                    let branch_first = matches!(
                        op, PlanOp::DilatedConv {
                            fan: Fan::BranchFirst, ..
                        });
                    let mut dstbuf = (i != last_prod)
                        .then(|| hnd.checkout(b * st.out_elems));
                    {
                        let dst: &mut [f32] = match &mut dstbuf {
                            Some(d) => d,
                            None => out,
                        };
                        let src: &[f32] = match &cursor {
                            Cursor::Input => xd,
                            Cursor::Buf(buf) => buf,
                            Cursor::Out => unreachable!(
                                "producer after the last producer"),
                        };
                        match op {
                            PlanOp::Project { w, in_dim, out_dim } => {
                                match st.tile {
                                    Some(tile) => {
                                        crate::gemm::sgemm_tiled_with(
                                            hnd, b, *out_dim, *in_dim,
                                            src, w.data(), dst, false,
                                            tile);
                                    }
                                    None => crate::gemm::sgemm_with(
                                        hnd, b, *out_dim, *in_dim, src,
                                        w.data(), dst, false),
                                }
                            }
                            PlanOp::TransposeConv { kernel, patterns,
                                                    seg, k, params, h,
                                                    c_in, .. }
                            => {
                                run_transpose_op(
                                    src, b, *h, *h, *c_in, kernel,
                                    patterns, *k, params,
                                    st.engine.unwrap(), st.threads,
                                    seg.as_deref(), dst, hnd);
                            }
                            PlanOp::DilatedConv { kernel, taps, params,
                                                  h, c_in, .. } => {
                                run_dilated_op(
                                    src, b, *h, *h, *c_in, kernel, taps,
                                    params, st.engine.unwrap(),
                                    st.threads, dst, hnd);
                            }
                            PlanOp::Head(Head::ArgmaxMask { classes }) => {
                                let [h, w, _] = st.out_shape;
                                crate::seg::argmax_into(
                                    src, b, h, w, *classes, dst);
                            }
                            PlanOp::Activation(_) => unreachable!(),
                        }
                    }
                    // retire the old activation; advance the cursor
                    let old = std::mem::replace(
                        &mut cursor,
                        match dstbuf {
                            Some(d) => Cursor::Buf(d),
                            None => Cursor::Out,
                        });
                    match old {
                        Cursor::Buf(buf) if branch_first => {
                            saved = Some(Cursor::Buf(buf));
                        }
                        Cursor::Input if branch_first => {
                            saved = Some(Cursor::Input);
                        }
                        Cursor::Buf(buf) => hnd.checkin(buf),
                        _ => {}
                    }
                }
            }
            if let Some((t0, b0)) = prof_t0 {
                let us = u64::try_from(t0.elapsed().as_micros())
                    .unwrap_or(u64::MAX);
                self.profile
                    .record(i, us, hnd.checked_out_bytes() - b0);
            }
        }
        if let Some(Cursor::Buf(old)) = saved.take() {
            hnd.checkin(old);
        }
        debug_assert!(matches!(cursor, Cursor::Out));
    }
}

fn push_step(steps: &mut Vec<PlanStep>, name: &str, op: PlanOp,
             engine: Option<Engine>, threads: usize,
             out_shape: [usize; 3], prepacked_bytes: usize) {
    steps.push(PlanStep {
        name: name.to_string(),
        out_elems: out_shape.iter().product(),
        op,
        engine,
        threads,
        tile: None,
        out_shape,
        prepacked_bytes,
    });
}

fn push_act(steps: &mut Vec<PlanStep>, a: Act) {
    let prev = steps.last().expect("activation follows a producer");
    let shape = prev.out_shape;
    push_step(steps, a.name(), PlanOp::Activation(a), None, 1, shape, 0);
}

/// Pooled scratch elements one step's engine checks out for batch `b`
/// (mirrors the checkouts in the engine bodies — the workspace
/// high-water computation, DESIGN.md §10).
fn step_scratch_elems(st: &PlanStep, b: usize) -> usize {
    use crate::gemm::{prepacked_scratch_elems, sgemm_scratch_elems};
    match &st.op {
        PlanOp::Project { out_dim, .. } => sgemm_scratch_elems(*out_dim),
        PlanOp::Activation(_) | PlanOp::Head(_) => 0,
        PlanOp::TransposeConv { patterns, k, params, h, c_in, c_out, .. }
        => {
            let ho = params.out_size(*h, *k);
            match st.engine {
                Some(Engine::Baseline) => {
                    let st_ = params.stride;
                    let (lo, hi) = params.inflate_pad(*k);
                    let ih = (*h - 1) * st_ + 1 + lo + hi;
                    b * ih * ih * c_in
                        + ho * ho * k * k * c_in
                        + sgemm_scratch_elems(*c_out)
                }
                Some(Engine::Segregated) => {
                    let (ply, phy, plx, phx) = huge2::pad_geometry(
                        patterns, *h, *h, ho, ho, params.stride);
                    let sub = ho.div_ceil(params.stride).pow(2);
                    let padded =
                        b * (*h + ply + phy) * (*h + plx + phx) * c_in;
                    // widest per-pattern col matrix (qy·qx ×
                    // taps_y·taps_x·C)
                    let col = patterns.iter()
                        .map(|pt| polyphase_len(ho, params.stride,
                                                pt.phi_y)
                            * polyphase_len(ho, params.stride, pt.phi_x)
                            * pt.ay.taps * pt.ax.taps * c_in)
                        .max()
                        .unwrap_or(0)
                        .max(1);
                    if st.threads > 1 {
                        // like MT HUGE²: every pattern's sub-output is
                        // live until the serial scatter; col matrices
                        // and GEMM panels are per live thread (the
                        // engine clamps shards to the pattern count)
                        let shards = st.threads.min(patterns.len().max(1));
                        padded
                            + params.stride * params.stride * sub * c_out
                            + shards * (col + prepacked_scratch_elems())
                    } else {
                        padded + sub * c_out + col
                            + prepacked_scratch_elems()
                    }
                }
                _ => {
                    let (ply, phy, plx, phx) = huge2::pad_geometry(
                        patterns, *h, *h, ho, ho, params.stride);
                    let sub = ho.div_ceil(params.stride).pow(2);
                    let padded =
                        b * (*h + ply + phy) * (*h + plx + phx) * c_in;
                    if st.threads > 1 {
                        // the MT engine holds EVERY pattern's sub-output
                        // (stride² of them) until the serial scatter,
                        // regardless of thread count; A-assembly buffers
                        // and GEMM panels are per live thread
                        let n_patterns = params.stride * params.stride;
                        padded + n_patterns * sub * c_out
                            + st.threads
                                * (sub * c_in + prepacked_scratch_elems())
                    } else {
                        // single-threaded: one sub + one A buffer,
                        // reused across patterns
                        padded + sub * c_out + sub * c_in
                            + prepacked_scratch_elems()
                    }
                }
            }
        }
        PlanOp::DilatedConv { taps, params, h, c_in, c_out, .. } => {
            let kk = taps.r;
            match st.engine {
                Some(Engine::Baseline) => {
                    let e = params.eff_kernel(kk);
                    let ho = params.out_size(*h, kk);
                    e * e * c_in * c_out
                        + ho * ho * e * e * c_in
                        + sgemm_scratch_elems(*c_out)
                }
                _ => {
                    b * (*h + 2 * params.pad).pow(2) * c_in
                        + st.threads * prepacked_scratch_elems()
                }
            }
        }
    }
}

/// FNV-1a64 over the plan's resolved selections.
fn digest_steps(requested: Option<Engine>, in_elems: usize,
                steps: &[PlanStep]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |s: &str| {
        for byte in s.as_bytes() {
            h ^= *byte as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        h ^= 0xff;
        h = h.wrapping_mul(0x100_0000_01b3);
    };
    eat(match requested {
        None => "per-layer",
        Some(e) => e.name(),
    });
    eat(&in_elems.to_string());
    // Relaxed-numerics GEMM tiers (the opt-in FMA kernel) change step
    // outputs bitwise, so they must change the digest: a trace recorded
    // under default numerics then replayed under FMA (or vice versa)
    // fails loudly at the header digest gate instead of silently
    // diverging on checksums. Default tiers (scalar / AVX2 mul+add) are
    // bit-identical and eat nothing — pre-existing traces still verify.
    let isa = crate::gemm::active_isa();
    if isa.relaxed_numerics() {
        eat(&format!("numerics:{}", isa.name()));
    }
    for st in steps {
        eat(&st.name);
        eat(st.op.kind());
        eat(st.engine.map(|e| e.name()).unwrap_or("-"));
        eat(&st.threads.to_string());
        eat(&format!("{:?}", st.out_shape));
        // Tuned non-default GEMM tiles regroup K-panel partial sums
        // (different FP accumulation order), so — like the FMA term —
        // they must change the digest. Untuned steps (tile = None, the
        // only state reachable without `with_tuning`) eat nothing, so
        // every pre-existing digest and trace stays valid.
        if let Some(t) = st.tile {
            if !t.is_default() {
                eat(&format!("tile:{}x{}", t.kc, t.nc));
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::tiny_segnet;
    use crate::gan::Generator;
    use crate::rng::Rng;
    use crate::seg::SegNet;
    use crate::workspace::Workspace;

    #[test]
    fn auto_resolution_is_shape_driven() {
        // stride 1: nothing to skip -> baseline
        let p1 = DeconvParams::new(1, 1, 0);
        assert_eq!(resolve_transpose(Engine::Auto, 8, 8, 4, 4, 3, &p1, 1),
                   (Engine::Baseline, 1));
        // stride 2, small -> huge2 single-thread
        let p2 = DeconvParams::new(2, 2, 1);
        assert_eq!(resolve_transpose(Engine::Auto, 8, 8, 4, 4, 5, &p2, 1),
                   (Engine::Huge2, 1));
        // stride 2, DC1-sized -> huge2 multi-threaded (AUTO_THREADS
        // clamped to whatever this host actually has)
        assert_eq!(
            resolve_transpose(Engine::Auto, 4, 4, 1024, 512, 5, &p2, 1),
            (Engine::Huge2, AUTO_THREADS.min(host_threads())));
        // concrete requests pass through (baseline is single-threaded)
        assert_eq!(resolve_transpose(Engine::Baseline, 4, 4, 8, 8, 5, &p2,
                                     7),
                   (Engine::Baseline, 1));
        assert_eq!(resolve_transpose(Engine::Huge2, 4, 4, 8, 8, 5, &p2, 7),
                   (Engine::Huge2, 7));
        assert_eq!(resolve_transpose(Engine::Segregated, 4, 4, 8, 8, 5,
                                     &p2, 3),
                   (Engine::Segregated, 3));
        // segregation targets transposed-conv zero-insertion; on the
        // dilated path the request falls through to the untangled engine
        let d0 = DilatedParams::new(2, 1, 2);
        assert_eq!(resolve_dilated(Engine::Segregated, 9, 9, 2, 4, 3, &d0,
                                   2),
                   (Engine::Huge2, 2));

        // dilated: dilation 1 + tiny -> baseline; dilation > 1 -> huge2
        let d1 = DilatedParams::new(1, 1, 1);
        assert_eq!(resolve_dilated(Engine::Auto, 9, 9, 2, 4, 3, &d1, 1),
                   (Engine::Baseline, 1));
        let d2 = DilatedParams::new(2, 1, 2);
        assert_eq!(resolve_dilated(Engine::Auto, 9, 9, 2, 4, 3, &d2, 1).0,
                   Engine::Huge2);
        // dilation 1 but heavy -> huge2 (prepacked taps win)
        assert_eq!(
            resolve_dilated(Engine::Auto, 33, 33, 64, 64, 3, &d1, 1).0,
            Engine::Huge2);
    }

    #[test]
    fn auto_threads_clamp_both_directions() {
        let heavy = AUTO_MT_MIN_MACS; // at the MT cutoff
        let light = AUTO_MT_MIN_MACS - 1;
        // host below the heuristic cap: clamped DOWN to the host
        assert_eq!(resolve_auto_threads(heavy, 2), 2);
        assert_eq!(resolve_auto_threads(heavy, 1), 1);
        // host above the cap: never above AUTO_THREADS
        assert_eq!(resolve_auto_threads(heavy, 64), AUTO_THREADS);
        assert_eq!(resolve_auto_threads(heavy, AUTO_THREADS),
                   AUTO_THREADS);
        // below the MT cutoff: single-threaded regardless of cores
        assert_eq!(resolve_auto_threads(light, 64), 1);
        // degenerate cap never resolves to zero threads
        assert_eq!(resolve_auto_threads(heavy, 0), 1);
        // the public resolvers honor the host clamp end to end
        let p2 = DeconvParams::new(2, 2, 1);
        let (_, t) = resolve_transpose(Engine::Auto, 4, 4, 1024, 512, 5,
                                       &p2, 1);
        assert!(t <= AUTO_THREADS && t <= host_threads(),
                "resolved {t} threads on a {}-core host", host_threads());
        let d1 = DilatedParams::new(1, 1, 1);
        let (_, td) = resolve_dilated(Engine::Auto, 65, 65, 64, 64, 3,
                                      &d1, 1);
        assert!(td <= AUTO_THREADS && td <= host_threads());
    }

    #[test]
    fn digest_tracks_engine_selection() {
        let gen = Generator::tiny_cgan(5);
        let a = ExecPlan::compile_gan(&gen.proj, &gen.layers, Engine::Auto);
        let a2 = ExecPlan::compile_gan(&gen.proj, &gen.layers,
                                       Engine::Auto);
        let b = ExecPlan::compile_gan(&gen.proj, &gen.layers,
                                      Engine::Baseline);
        assert_eq!(a.engine_digest(), a2.engine_digest(),
                   "digest must be deterministic");
        assert_ne!(a.engine_digest(), b.engine_digest(),
                   "digest must see engine changes");
        let net = SegNet::new(&tiny_segnet(), 5);
        let s = net.plan();
        assert_ne!(s.engine_digest(), a.engine_digest());
        assert_ne!(s.with_argmax_head(3).engine_digest(),
                   s.engine_digest(), "head changes the digest");
    }

    #[test]
    fn plan_shapes_and_high_water() {
        let gen = Generator::tiny_cgan(5);
        let plan = gen.plan();
        assert_eq!(plan.in_elems(), 8);
        assert_eq!(plan.out_shape(3), vec![3, 32, 32, 3]);
        assert!(plan.prepacked_bytes() > 0);
        assert!(plan.high_water_elems(1) > 0);
        assert!(plan.high_water_elems(4) > plan.high_water_elems(1));

        let net = SegNet::new(&tiny_segnet(), 5);
        let serve = net.plan().with_argmax_head(net.n_classes());
        assert_eq!(serve.out_shape(2), vec![2, 9, 9, 1]);
        assert_eq!(net.plan().out_shape(2), vec![2, 9, 9, 3]);
    }

    #[test]
    fn profiler_records_only_when_enabled() {
        let ws = Workspace::new();
        let gen = Generator::tiny_cgan(5);
        let plan = gen.plan();
        let z = Tensor::randn(&[2, 8], &mut Rng::new(4));

        // off by default: no samples
        let baseline = plan.run(&z, &mut ws.handle());
        assert_eq!(plan.profile().runs(), 0);

        plan.profile().set_enabled(true);
        for _ in 0..3 {
            let got = plan.run(&z, &mut ws.handle());
            assert_eq!(got.checksum(), baseline.checksum(),
                       "profiling must not perturb outputs");
        }
        assert_eq!(plan.profile().runs(), 3);
        for i in 0..plan.steps().len() {
            let p = plan.profile().step(i);
            assert_eq!(p.count, 3, "step {i} records once per run");
            assert!(p.max_us >= p.ewma_us as u64 || p.max_us == 0);
            assert!(p.mean_us >= 0.0);
        }
        // conv steps check out activation slabs; byte attribution > 0
        let conv_idx = plan.steps().iter()
            .position(|s| s.op.kind() == "transpose-conv")
            .unwrap();
        assert!(plan.profile().step(conv_idx).ws_bytes > 0,
                "conv steps must attribute workspace bytes");

        plan.profile().reset();
        assert_eq!(plan.profile().runs(), 0);

        // the profile is shared across clones
        let clone = plan.clone();
        clone.run(&z, &mut ws.handle());
        assert_eq!(plan.profile().runs(), 1,
                   "clones must fold into one profile");
        plan.profile().set_enabled(false);
    }

    #[test]
    fn profile_report_is_digest_keyed_and_complete() {
        let ws = Workspace::new();
        let gen = Generator::tiny_cgan(5);
        let plan = ExecPlan::compile_gan(&gen.proj, &gen.layers,
                                         Engine::Auto);
        plan.profile().set_enabled(true);
        let z = Tensor::randn(&[1, 8], &mut Rng::new(5));
        plan.run(&z, &mut ws.handle());
        let report = plan.profile_report();
        let mut lines = report.lines();
        let header = lines.next().unwrap();
        assert!(header.starts_with("# huge2 plan profile v1 digest="),
                "{header}");
        assert!(header.contains(
            &format!("digest={:016x}", plan.engine_digest())), "{header}");
        assert!(header.contains(&format!("steps={}", plan.steps().len())));
        let body: Vec<&str> = lines.collect();
        assert_eq!(body.len(), plan.steps().len());
        for (i, (line, st)) in
            body.iter().zip(plan.steps()).enumerate()
        {
            let cols: Vec<&str> = line.split_whitespace().collect();
            assert_eq!(cols.len(), 10, "line {i}: {line}");
            assert_eq!(cols[0], i.to_string());
            assert_eq!(cols[1], st.name);
            assert_eq!(cols[2], st.op.kind());
            assert_eq!(cols[5], "1", "one profiled run");
        }
    }

    #[test]
    fn plan_run_matches_model_forward() {
        let ws = Workspace::new();
        let gen = Generator::tiny_cgan(5);
        let z = Tensor::randn(&[2, 8], &mut Rng::new(3));
        for e in [Engine::Baseline, Engine::Huge2, Engine::Segregated,
                  Engine::Auto] {
            let plan = ExecPlan::compile_gan(&gen.proj, &gen.layers, e);
            let got = plan.run(&z, &mut ws.handle());
            let want = gen.forward(&z, e);
            assert_eq!(got.checksum(), want.checksum(), "{e:?}");
        }
    }

    #[test]
    fn segregated_plan_compiles_with_fused_panels() {
        let gen = Generator::tiny_cgan(5);
        let plan = ExecPlan::compile_gan(&gen.proj, &gen.layers,
                                         Engine::Segregated);
        assert!(plan.resolves_to(Engine::Segregated));
        assert!(plan.prepacked_bytes() > 0);
        assert!(plan.high_water_elems(1) > 0);
        for st in plan.steps() {
            if let PlanOp::TransposeConv { seg, .. } = &st.op {
                assert!(seg.is_some(),
                        "segregated step must carry fused panels");
            }
        }
        let auto = ExecPlan::compile_gan(&gen.proj, &gen.layers,
                                         Engine::Auto);
        assert_ne!(plan.engine_digest(), auto.engine_digest(),
                   "digest must see the third engine");
        // Auto never picks Segregated: existing digests stay valid
        for st in auto.steps() {
            assert_ne!(st.engine, Some(Engine::Segregated));
            if let PlanOp::TransposeConv { seg, .. } = &st.op {
                assert!(seg.is_none(),
                        "non-segregated steps pack no fused panels");
            }
        }
        // with_threads forces segregated steps too (the grid's lever)
        let mt = plan.with_threads(3);
        for st in mt.steps() {
            if st.engine == Some(Engine::Segregated) {
                assert_eq!(st.threads, 3);
            }
        }
    }

    #[test]
    fn with_tuning_applies_selections_and_tracks_digest() {
        let ws = Workspace::new();
        let gen = Generator::tiny_cgan(5);
        let plan = ExecPlan::compile_gan(&gen.proj, &gen.layers,
                                         Engine::Auto);
        let z = Tensor::randn(&[2, 8], &mut Rng::new(6));
        let want = plan.run(&z, &mut ws.handle());

        // identity tuning (selections match the compiled plan exactly):
        // digest-identical, bit-identical
        let same = PlanTuning {
            selections: plan.steps().iter().enumerate()
                .map(|(i, st)| StepSelection {
                    step: i,
                    engine: st.engine,
                    threads: st.threads,
                    tile: None,
                })
                .collect(),
        };
        let tuned_same = plan.with_tuning(&same);
        assert_eq!(tuned_same.engine_digest(), plan.engine_digest(),
                   "matching selections must not move the digest");
        assert_eq!(tuned_same.run(&z, &mut ws.handle()).checksum(),
                   want.checksum());

        // engine flips: segregated step gains fused panels, digest
        // moves, outputs stay numerically identical (bit-identical
        // engines, DESIGN.md §14)
        let mut flips = Vec::new();
        for (i, st) in plan.steps().iter().enumerate() {
            if matches!(st.op, PlanOp::TransposeConv { .. }) {
                flips.push(StepSelection {
                    step: i,
                    engine: Some(Engine::Segregated),
                    threads: 2,
                    tile: None,
                });
            }
        }
        assert!(!flips.is_empty());
        let tuned = plan.with_tuning(&PlanTuning { selections: flips });
        assert_ne!(tuned.engine_digest(), plan.engine_digest(),
                   "differing selections must move the digest");
        for st in tuned.steps() {
            if let PlanOp::TransposeConv { seg, .. } = &st.op {
                assert_eq!(st.engine, Some(Engine::Segregated));
                assert_eq!(st.threads, 2);
                assert!(seg.is_some(), "flip must pack fused panels");
            }
        }
        assert_eq!(tuned.run(&z, &mut ws.handle()).checksum(),
                   want.checksum(),
                   "tuned plans stay bit-identical across engines");

        // a non-default Project tile moves the digest (numerics term)
        let proj = plan.steps().iter()
            .position(|s| matches!(s.op, PlanOp::Project { .. }))
            .unwrap();
        let tiled = plan.with_tuning(&PlanTuning {
            selections: vec![StepSelection {
                step: proj,
                engine: None,
                threads: 1,
                tile: Some(Tile { kc: 128, nc: 512 }),
            }],
        });
        assert_ne!(tiled.engine_digest(), plan.engine_digest());
        // default tile is a no-op: digest unchanged
        let default_tile = plan.with_tuning(&PlanTuning {
            selections: vec![StepSelection {
                step: proj,
                engine: None,
                threads: 1,
                tile: Some(Tile::DEFAULT),
            }],
        });
        assert_eq!(default_tile.engine_digest(), plan.engine_digest());
    }
}
