//! Stage spans: per-request lifecycle stamps and the named per-stage
//! latency histograms they aggregate into (DESIGN.md §12).
//!
//! A request's wall time decomposes into five consecutive stages:
//!
//! | stage        | from → to                                   |
//! |--------------|---------------------------------------------|
//! | `queue_wait` | enqueued → popped by a worker               |
//! | `batch_form` | popped → batch closed                       |
//! | `gather`     | batch closed → forward starts (validation + |
//! |              | latent/image gather)                        |
//! | `forward`    | forward start → forward end (plan/backend)  |
//! | `reply`      | forward end → outcome sent                  |
//!
//! Each stage is a [`Histogram`] keyed by `(task, outcome)`, registered
//! as `huge2_stage_<stage>_us{task="…",outcome="…"}` — so a failed
//! segment request's queue wait is quantile-able separately from a
//! completed generate request's.
//!
//! Cost model: stamps are `Copy` [`Instant`]s carried inside the
//! request struct (no allocation); recording is one saturating
//! subtraction plus a lock-free histogram increment per stage, only
//! when instrumentation is enabled.

use super::registry::MetricsRegistry;
use super::{Histogram, HistogramSnapshot};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Stage names, chain order. Indexes match the `STAGE_*` constants.
pub const STAGES: [&str; 5] =
    ["queue_wait", "batch_form", "gather", "forward", "reply"];
pub const STAGE_QUEUE_WAIT: usize = 0;
pub const STAGE_BATCH_FORM: usize = 1;
pub const STAGE_GATHER: usize = 2;
pub const STAGE_FORWARD: usize = 3;
pub const STAGE_REPLY: usize = 4;

/// Task label values, indexed by `Task::index()`.
pub const TASKS: [&str; 2] = ["generate", "segment"];

/// Outcome label values, indexed by `SpanOutcome as usize`.
pub const OUTCOMES: [&str; 2] = ["completed", "failed"];

/// Terminal outcome of a *worker-delivered* request (submit-side
/// rejects never reach the staged pipeline, so they are not a span
/// outcome).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanOutcome {
    Completed = 0,
    Failed = 1,
}

/// Per-request lifecycle stamps, threaded through the coordinator
/// inside the request itself. `Copy`, two optional `Instant`s — no
/// heap, no atomics; the submit-side stamp is always present, the
/// worker-side stamps are filled in as the request advances.
#[derive(Debug, Clone, Copy)]
pub struct SpanStamps {
    /// `Engine::submit` entry (the request's birth).
    pub submitted: Instant,
    /// A worker popped the request off the queue.
    pub popped: Option<Instant>,
    /// The batch containing the request closed.
    pub batched: Option<Instant>,
}

impl SpanStamps {
    pub fn now() -> Self {
        SpanStamps { submitted: Instant::now(), popped: None, batched: None }
    }
}

/// One stage's histograms across the `(task, outcome)` label grid.
#[derive(Debug)]
struct StageSet {
    /// `[task][outcome]`, indexed by `Task::index()` / `SpanOutcome`.
    cells: [[Arc<Histogram>; 2]; 2],
}

/// The five per-stage histogram grids, registered in a
/// [`MetricsRegistry`] under `huge2_stage_<stage>_us{task,outcome}`.
#[derive(Debug)]
pub struct StageMetrics {
    stages: [StageSet; 5],
}

impl StageMetrics {
    /// Build the full stage × task × outcome grid and register every
    /// series in `reg`.
    pub fn new(reg: &MetricsRegistry) -> Self {
        let stages = std::array::from_fn(|s| {
            let cells = std::array::from_fn(|t| {
                std::array::from_fn(|o| {
                    reg.histogram(&format!(
                        "huge2_stage_{}_us{{task=\"{}\",outcome=\"{}\"}}",
                        STAGES[s], TASKS[t], OUTCOMES[o]
                    ))
                })
            });
            StageSet { cells }
        });
        StageMetrics { stages }
    }

    /// Record one stage sample for a `(task, outcome)` cell. `task` is
    /// `Task::index()`; out-of-range indices are clamped (defensive —
    /// the coordinator only passes 0/1).
    #[inline]
    pub fn record(
        &self,
        task: usize,
        outcome: SpanOutcome,
        stage: usize,
        d: Duration,
    ) {
        self.stages[stage.min(4)].cells[task.min(1)][outcome as usize]
            .record(d);
    }

    /// Direct access to one cell's histogram.
    pub fn cell(
        &self,
        task: usize,
        outcome: SpanOutcome,
        stage: usize,
    ) -> &Histogram {
        &self.stages[stage.min(4)].cells[task.min(1)][outcome as usize]
    }

    /// One stage's distribution merged across every `(task, outcome)`
    /// cell — the "where does time go overall" view the shutdown
    /// summary prints.
    pub fn merged(&self, stage: usize) -> HistogramSnapshot {
        let mut out = HistogramSnapshot::empty();
        for t in 0..2 {
            for o in 0..2 {
                out.merge(&self.stages[stage.min(4)].cells[t][o].snapshot());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stamps_are_copy_and_start_unfilled() {
        let s = SpanStamps::now();
        let s2 = s; // Copy
        assert!(s2.popped.is_none());
        assert!(s2.batched.is_none());
        assert!(s.submitted.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn record_lands_in_the_right_cell() {
        let reg = MetricsRegistry::new();
        let sm = StageMetrics::new(&reg);
        sm.record(0, SpanOutcome::Completed, STAGE_FORWARD,
                  Duration::from_micros(100));
        sm.record(1, SpanOutcome::Failed, STAGE_FORWARD,
                  Duration::from_micros(900));
        assert_eq!(sm.cell(0, SpanOutcome::Completed, STAGE_FORWARD)
                       .count(), 1);
        assert_eq!(sm.cell(1, SpanOutcome::Failed, STAGE_FORWARD).count(),
                   1);
        assert_eq!(sm.cell(0, SpanOutcome::Failed, STAGE_FORWARD).count(),
                   0);
        let merged = sm.merged(STAGE_FORWARD);
        assert_eq!(merged.count(), 2);
        assert!(merged.max_us() >= 900);
        assert_eq!(sm.merged(STAGE_REPLY).count(), 0);
    }

    #[test]
    fn registry_sees_every_labeled_series() {
        let reg = MetricsRegistry::new();
        let _sm = StageMetrics::new(&reg);
        let snap = reg.snapshot();
        let text = snap.to_prometheus();
        for stage in STAGES {
            for task in TASKS {
                for outcome in OUTCOMES {
                    let needle = format!(
                        "huge2_stage_{stage}_us{{task=\"{task}\",\
                         outcome=\"{outcome}\""
                    );
                    assert!(text.contains(&needle), "missing {needle}");
                }
            }
        }
    }
}
