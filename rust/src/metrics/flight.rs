//! Flight recorder: a fixed-size, lock-free ring of recent span events
//! (request id, stage, timestamp, worker lane), dumped by the worker
//! supervision path on a caught panic and on demand (DESIGN.md §12).
//!
//! Semantics:
//!
//! * **Writers never block and never allocate.** A push is one global
//!   ticket `fetch_add` plus three slot stores — O(1), wait-free for
//!   the counter, per-slot seqlock for the payload.
//! * **Counts are exact.** `pushed()` comes from the ticket counter
//!   alone, so `overwrites() == pushed().saturating_sub(capacity)`
//!   holds *exactly* even under arbitrary concurrent wrap — the
//!   overwrite-accounting property `tests/fault_stack.rs` soaks.
//! * **Reads are best-effort while writers are active.** A snapshot
//!   validates each slot's sequence word before and after reading the
//!   payload and skips slots that are mid-write or already lapped; a
//!   *quiescent* dump (panic path after `catch_unwind`, drained engine)
//!   is complete and ordered oldest → newest.
//!
//! Each slot is a miniature seqlock built from plain atomics (no
//! `UnsafeCell`, no `unsafe`): a writer claims ticket `t`, stamps the
//! slot's `seq` to the odd value `2t+1`, stores the payload words, then
//! publishes `seq = 2t+2`. A reader requires the even value for the
//! ticket it expects, reads the payload, and re-checks `seq`.

use std::sync::atomic::Ordering::{Acquire, Relaxed, Release};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Worker lane recorded for events that happen before a worker owns the
/// request (submit-side stages: `Submitted`, `Enqueued`, `Rejected`).
pub const SUBMIT_LANE: u32 = 0xFF;

/// Request lifecycle stages, in order. The stage chain of a terminal
/// outcome is monotone: a completed request passes through every stage
/// `Submitted → … → Completed`; a rejected one stops at `Rejected`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Stage {
    /// `Engine::submit` accepted the call and assigned an id.
    Submitted = 0,
    /// The request entered its model's bounded queue.
    Enqueued = 1,
    /// Refused at submit time (validation, backpressure, shutdown) —
    /// terminal.
    Rejected = 2,
    /// A worker popped the request off the queue.
    Popped = 3,
    /// The batch containing the request closed (batching window ended).
    Batched = 4,
    /// Gather/validation of the batch's payloads began.
    GatherStart = 5,
    /// The plan/backend forward pass began.
    ForwardStart = 6,
    /// The forward pass produced outputs.
    ForwardEnd = 7,
    /// A `Response` was sent through the reply channel — terminal.
    Completed = 8,
    /// A typed `ServeError` was sent through the reply channel —
    /// terminal.
    Failed = 9,
}

impl Stage {
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Submitted => "submitted",
            Stage::Enqueued => "enqueued",
            Stage::Rejected => "rejected",
            Stage::Popped => "popped",
            Stage::Batched => "batched",
            Stage::GatherStart => "gather_start",
            Stage::ForwardStart => "forward_start",
            Stage::ForwardEnd => "forward_end",
            Stage::Completed => "completed",
            Stage::Failed => "failed",
        }
    }

    pub fn from_u8(v: u8) -> Option<Stage> {
        Some(match v {
            0 => Stage::Submitted,
            1 => Stage::Enqueued,
            2 => Stage::Rejected,
            3 => Stage::Popped,
            4 => Stage::Batched,
            5 => Stage::GatherStart,
            6 => Stage::ForwardStart,
            7 => Stage::ForwardEnd,
            8 => Stage::Completed,
            9 => Stage::Failed,
            _ => return None,
        })
    }

    /// Terminal stages end a request's chain: exactly one of these per
    /// accepted request (the outcome-conservation invariant, §11).
    pub fn is_terminal(&self) -> bool {
        matches!(self, Stage::Rejected | Stage::Completed | Stage::Failed)
    }
}

/// A decoded ring entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlightEvent {
    /// Global push ordinal (0-based); total order across all writers.
    pub ticket: u64,
    /// Request id the event belongs to.
    pub id: u64,
    pub stage: Stage,
    /// µs since recorder creation (48-bit, clamped).
    pub t_us: u64,
    /// Worker index, or [`SUBMIT_LANE`] for submit-side events.
    pub worker: u32,
}

/// One ring slot: a seqlock word plus two payload words, all plain
/// atomics. `seq == 0` means never written; `2t+1` means ticket `t` is
/// mid-write; `2t+2` means ticket `t` is published.
#[derive(Debug)]
struct Slot {
    seq: AtomicU64,
    id: AtomicU64,
    /// `t_us << 16 | worker << 8 | stage` (t_us clamped to 48 bits).
    packed: AtomicU64,
}

/// The ring. See module docs for the write/read protocol.
#[derive(Debug)]
pub struct FlightRecorder {
    t0: Instant,
    slots: Vec<Slot>,
    next: AtomicU64,
}

const T_US_MAX: u64 = (1u64 << 48) - 1; // ~8.9 years of µs

impl FlightRecorder {
    /// A ring of `capacity` slots (floored at 1).
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        let mut slots = Vec::with_capacity(capacity);
        slots.resize_with(capacity, || Slot {
            seq: AtomicU64::new(0),
            id: AtomicU64::new(0),
            packed: AtomicU64::new(0),
        });
        FlightRecorder { t0: Instant::now(), slots, next: AtomicU64::new(0) }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Record one span event. Wait-free ticket claim, then a per-slot
    /// seqlock write; no allocation, no lock.
    pub fn record(&self, id: u64, stage: Stage, worker: u32) {
        let t_us = u64::try_from(self.t0.elapsed().as_micros())
            .unwrap_or(T_US_MAX)
            .min(T_US_MAX);
        let ticket = self.next.fetch_add(1, Relaxed);
        let slot = &self.slots[(ticket % self.slots.len() as u64) as usize];
        let packed =
            (t_us << 16) | (u64::from(worker & 0xFF) << 8) | stage as u64;
        slot.seq.store(2 * ticket + 1, Release);
        slot.id.store(id, Relaxed);
        slot.packed.store(packed, Relaxed);
        slot.seq.store(2 * ticket + 2, Release);
    }

    /// Total events ever pushed (exact; from the ticket counter alone).
    pub fn pushed(&self) -> u64 {
        self.next.load(Ordering::Relaxed)
    }

    /// Events lost to ring wrap — exact by construction:
    /// `pushed() - capacity` once the ring has wrapped, 0 before.
    pub fn overwrites(&self) -> u64 {
        self.pushed().saturating_sub(self.slots.len() as u64)
    }

    /// Attempt to read the slot that ticket `t` published. `None` if
    /// the slot is mid-write, already lapped, or torn.
    fn read_ticket(&self, t: u64) -> Option<FlightEvent> {
        let slot = &self.slots[(t % self.slots.len() as u64) as usize];
        let want = 2 * t + 2;
        if slot.seq.load(Acquire) != want {
            return None;
        }
        let id = slot.id.load(Relaxed);
        let packed = slot.packed.load(Relaxed);
        if slot.seq.load(Acquire) != want {
            return None; // torn: a writer lapped us mid-read
        }
        Some(FlightEvent {
            ticket: t,
            id,
            stage: Stage::from_u8((packed & 0xFF) as u8)?,
            t_us: packed >> 16,
            worker: ((packed >> 8) & 0xFF) as u32,
        })
    }

    /// The surviving ring contents, oldest → newest by ticket. Skips
    /// slots that are mid-write or got lapped during the scan (only
    /// possible while writers are concurrently active); a quiescent
    /// snapshot returns exactly `min(pushed, capacity)` events.
    pub fn snapshot(&self) -> Vec<FlightEvent> {
        let pushed = self.pushed();
        let cap = self.slots.len() as u64;
        let start = pushed.saturating_sub(cap);
        (start..pushed).filter_map(|t| self.read_ticket(t)).collect()
    }

    /// All surviving events for one request id, oldest → newest — the
    /// per-request stage chain, as far as the ring still holds it.
    pub fn events_for(&self, id: u64) -> Vec<FlightEvent> {
        let mut evs = self.snapshot();
        evs.retain(|e| e.id == id);
        evs
    }

    /// Human-readable dump of the most recent `limit` surviving events
    /// (the panic-path excerpt). One line per event:
    /// `#<ticket> +<t_us>µs req=<id> <stage> worker=<n|submit>`.
    pub fn excerpt(&self, limit: usize) -> String {
        use std::fmt::Write as _;
        let evs = self.snapshot();
        let skip = evs.len().saturating_sub(limit);
        let mut out = String::new();
        let _ = writeln!(
            out,
            "flight recorder: {} of {} event(s) retained \
             ({} overwritten), last {}:",
            evs.len(),
            self.pushed(),
            self.overwrites(),
            evs.len() - skip
        );
        for e in &evs[skip..] {
            let _ = write!(out, "  #{} +{}µs req={} {}", e.ticket, e.t_us,
                           e.id, e.stage.name());
            if e.worker == SUBMIT_LANE {
                let _ = writeln!(out, " worker=submit");
            } else {
                let _ = writeln!(out, " worker={}", e.worker);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_round_trips_through_u8() {
        for v in 0u8..=9 {
            let s = Stage::from_u8(v).unwrap();
            assert_eq!(s as u8, v);
            assert!(!s.name().is_empty());
        }
        assert_eq!(Stage::from_u8(10), None);
        assert!(Stage::Completed.is_terminal());
        assert!(Stage::Failed.is_terminal());
        assert!(Stage::Rejected.is_terminal());
        assert!(!Stage::ForwardEnd.is_terminal());
    }

    #[test]
    fn quiescent_snapshot_is_complete_and_ordered() {
        let fr = FlightRecorder::new(8);
        for i in 0..5u64 {
            fr.record(i, Stage::Submitted, SUBMIT_LANE);
        }
        let evs = fr.snapshot();
        assert_eq!(evs.len(), 5);
        assert_eq!(fr.pushed(), 5);
        assert_eq!(fr.overwrites(), 0);
        for (i, e) in evs.iter().enumerate() {
            assert_eq!(e.ticket, i as u64);
            assert_eq!(e.id, i as u64);
            assert_eq!(e.stage, Stage::Submitted);
            assert_eq!(e.worker, SUBMIT_LANE);
        }
        for w in evs.windows(2) {
            assert!(w[0].t_us <= w[1].t_us, "per-writer time is monotone");
        }
    }

    #[test]
    fn wrap_keeps_newest_and_counts_overwrites() {
        let fr = FlightRecorder::new(4);
        for i in 0..10u64 {
            fr.record(i, Stage::Popped, 2);
        }
        assert_eq!(fr.pushed(), 10);
        assert_eq!(fr.overwrites(), 6);
        let evs = fr.snapshot();
        assert_eq!(evs.len(), 4, "only the newest capacity events survive");
        let ids: Vec<u64> = evs.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9]);
        assert!(evs.iter().all(|e| e.worker == 2));
    }

    #[test]
    fn events_for_filters_one_request() {
        let fr = FlightRecorder::new(32);
        fr.record(7, Stage::Submitted, SUBMIT_LANE);
        fr.record(8, Stage::Submitted, SUBMIT_LANE);
        fr.record(7, Stage::Enqueued, SUBMIT_LANE);
        fr.record(7, Stage::Completed, 0);
        let chain: Vec<Stage> =
            fr.events_for(7).iter().map(|e| e.stage).collect();
        assert_eq!(chain,
                   vec![Stage::Submitted, Stage::Enqueued, Stage::Completed]);
    }

    #[test]
    fn excerpt_names_requests_and_lanes() {
        let fr = FlightRecorder::new(8);
        fr.record(42, Stage::Submitted, SUBMIT_LANE);
        fr.record(42, Stage::Failed, 1);
        let text = fr.excerpt(10);
        assert!(text.contains("req=42"), "{text}");
        assert!(text.contains("submitted"), "{text}");
        assert!(text.contains("failed"), "{text}");
        assert!(text.contains("worker=submit"), "{text}");
        assert!(text.contains("worker=1"), "{text}");
    }

    #[test]
    fn concurrent_pushes_never_lose_counts() {
        let fr = std::sync::Arc::new(FlightRecorder::new(16));
        let threads = 4u64;
        let per = 1000u64;
        let mut joins = Vec::new();
        for t in 0..threads {
            let fr = fr.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..per {
                    fr.record(t * per + i, Stage::Enqueued, t as u32);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(fr.pushed(), threads * per);
        assert_eq!(fr.overwrites(), threads * per - 16);
        // quiescent post-soak snapshot: full ring, ordered tickets
        let evs = fr.snapshot();
        assert_eq!(evs.len(), 16);
        for w in evs.windows(2) {
            assert!(w[0].ticket < w[1].ticket);
        }
    }
}
