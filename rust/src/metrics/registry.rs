//! Metrics registry: a named catalogue of counters, gauges and
//! histograms with atomic point-in-time snapshots, snapshot deltas, and
//! a Prometheus-style text exposition (DESIGN.md §12).
//!
//! Names are full series names *including* any label set, e.g.
//! `huge2_stage_forward_us{task="generate",outcome="completed"}` —
//! labels are part of the key, not a separate dimension, which keeps
//! the registry a flat `BTreeMap` (and makes the exposition ordering
//! deterministic: same-base-name series sort adjacent).
//!
//! Hand-rolled, zero dependencies: instruments are the crate's own
//! atomics; "snapshot" means one pass loading every instrument while
//! holding the catalogue lock — new registrations can't interleave, and
//! each histogram copy is internally consistent
//! ([`super::Histogram::snapshot`]).

use super::{Histogram, HistogramSnapshot};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

/// One registered instrument.
enum Instrument {
    /// A shared monotonic counter.
    Counter(Arc<AtomicU64>),
    /// A counter read through a closure (adapts pre-existing atomics —
    /// engine `Counters`, workspace counters — without restructuring
    /// them).
    CounterFn(Box<dyn Fn() -> u64 + Send + Sync>),
    /// A point-in-time signed gauge read through a closure (queue
    /// depth, in-flight).
    GaugeFn(Box<dyn Fn() -> i64 + Send + Sync>),
    /// A shared latency histogram.
    Hist(Arc<Histogram>),
}

impl std::fmt::Debug for Instrument {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = match self {
            Instrument::Counter(_) => "Counter",
            Instrument::CounterFn(_) => "CounterFn",
            Instrument::GaugeFn(_) => "GaugeFn",
            Instrument::Hist(_) => "Hist",
        };
        f.write_str(kind)
    }
}

/// The catalogue. Registration replaces any previous instrument under
/// the same name (latest wins — re-registering a model's gauge after a
/// re-register is not an error).
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    items: Mutex<BTreeMap<String, Instrument>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or fetch) a plain counter under `name`.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut g = self.items.lock().unwrap();
        if let Some(Instrument::Counter(c)) = g.get(name) {
            return c.clone();
        }
        let c = Arc::new(AtomicU64::new(0));
        g.insert(name.to_string(), Instrument::Counter(c.clone()));
        c
    }

    /// Register a counter backed by a closure over an existing atomic.
    pub fn counter_fn(
        &self,
        name: &str,
        f: impl Fn() -> u64 + Send + Sync + 'static,
    ) {
        self.items
            .lock()
            .unwrap()
            .insert(name.to_string(), Instrument::CounterFn(Box::new(f)));
    }

    /// Register a gauge backed by a closure.
    pub fn gauge_fn(
        &self,
        name: &str,
        f: impl Fn() -> i64 + Send + Sync + 'static,
    ) {
        self.items
            .lock()
            .unwrap()
            .insert(name.to_string(), Instrument::GaugeFn(Box::new(f)));
    }

    /// Register (or fetch) a histogram under `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut g = self.items.lock().unwrap();
        if let Some(Instrument::Hist(h)) = g.get(name) {
            return h.clone();
        }
        let h = Arc::new(Histogram::new());
        g.insert(name.to_string(), Instrument::Hist(h.clone()));
        h
    }

    /// Register an *existing* histogram (e.g. the engine's batch
    /// execution histogram) under `name`.
    pub fn register_histogram(&self, name: &str, h: Arc<Histogram>) {
        self.items
            .lock()
            .unwrap()
            .insert(name.to_string(), Instrument::Hist(h));
    }

    /// Atomically snapshot every instrument: the catalogue lock is held
    /// for the whole pass, so the set of series is a consistent cut
    /// (individual atomics are read `Relaxed`; each histogram copy is
    /// internally consistent).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.items.lock().unwrap();
        let mut counters = BTreeMap::new();
        let mut gauges = BTreeMap::new();
        let mut histograms = BTreeMap::new();
        for (name, inst) in g.iter() {
            match inst {
                Instrument::Counter(c) => {
                    counters.insert(name.clone(), c.load(Relaxed));
                }
                Instrument::CounterFn(f) => {
                    counters.insert(name.clone(), f());
                }
                Instrument::GaugeFn(f) => {
                    gauges.insert(name.clone(), f());
                }
                Instrument::Hist(h) => {
                    histograms.insert(name.clone(), h.snapshot());
                }
            }
        }
        MetricsSnapshot { counters, gauges, histograms }
    }
}

/// A point-in-time copy of every registered instrument.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

impl MetricsSnapshot {
    /// What happened *between* `earlier` and `self`: counters subtract
    /// (saturating), histograms subtract bucket-wise
    /// ([`HistogramSnapshot::delta_since`]), gauges keep their current
    /// value (a gauge has no meaningful delta). Series absent from
    /// `earlier` count from zero.
    pub fn delta(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        let counters = self
            .counters
            .iter()
            .map(|(k, &v)| {
                let old = earlier.counters.get(k).copied().unwrap_or(0);
                (k.clone(), v.saturating_sub(old))
            })
            .collect();
        let histograms = self
            .histograms
            .iter()
            .map(|(k, h)| {
                let d = match earlier.histograms.get(k) {
                    Some(old) => h.delta_since(old),
                    None => h.clone(),
                };
                (k.clone(), d)
            })
            .collect();
        MetricsSnapshot {
            counters,
            gauges: self.gauges.clone(),
            histograms,
        }
    }

    /// Sum of `counters` whose series name starts with `prefix`
    /// (convenience for label-blind totals, e.g. all
    /// `huge2_stage_forward_us` cells).
    pub fn counter_sum(&self, prefix: &str) -> u64 {
        self.counters
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(_, &v)| v)
            .sum()
    }

    /// Merge every histogram series whose name starts with `prefix`
    /// into one distribution.
    pub fn merged_histogram(&self, prefix: &str) -> HistogramSnapshot {
        let mut out = HistogramSnapshot::empty();
        for (k, h) in &self.histograms {
            if k.starts_with(prefix) {
                out.merge(h);
            }
        }
        out
    }

    /// Prometheus-style text exposition. Counters render as
    /// `name value`; gauges likewise; histograms render as quantile
    /// series (`{quantile="0.5"}` etc.) plus `_sum` and `_count`.
    /// `# TYPE` comment lines appear once per base name (the part
    /// before any `{`) — `BTreeMap` ordering keeps same-base series
    /// adjacent, so one pass suffices.
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mut last_base = String::new();
        let mut type_line =
            |out: &mut String, name: &str, kind: &str| {
                let base = name.split('{').next().unwrap_or(name);
                if base != last_base {
                    let _ = writeln!(out, "# TYPE {base} {kind}");
                    last_base = base.to_string();
                }
            };
        for (name, v) in &self.counters {
            type_line(&mut out, name, "counter");
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, v) in &self.gauges {
            type_line(&mut out, name, "gauge");
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, h) in &self.histograms {
            type_line(&mut out, name, "summary");
            for q in ["0.5", "0.95", "0.99"] {
                let series = inject_label(
                    name,
                    &format!("quantile=\"{q}\""),
                );
                let qv = h.quantile_us(match q {
                    "0.5" => 0.5,
                    "0.95" => 0.95,
                    _ => 0.99,
                });
                let _ = writeln!(out, "{series} {qv}");
            }
            let _ = writeln!(out, "{} {}", suffix_name(name, "_sum"),
                             h.sum_us());
            let _ = writeln!(out, "{} {}", suffix_name(name, "_count"),
                             h.count());
        }
        out
    }
}

/// Insert `label` into `name`'s label set:
/// `m{a="b"}` → `m{a="b",quantile="0.5"}`, `m` → `m{quantile="0.5"}`.
fn inject_label(name: &str, label: &str) -> String {
    match name.strip_suffix('}') {
        Some(head) => format!("{head},{label}}}"),
        None => format!("{name}{{{label}}}"),
    }
}

/// Append `suffix` to the base name, preserving any label set:
/// `m{a="b"}` → `m_sum{a="b"}`, `m` → `m_sum`.
fn suffix_name(name: &str, suffix: &str) -> String {
    match name.find('{') {
        Some(i) => format!("{}{}{}", &name[..i], suffix, &name[i..]),
        None => format!("{name}{suffix}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counters_and_gauges_snapshot() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("huge2_test_total");
        c.fetch_add(3, Relaxed);
        let shared = Arc::new(AtomicU64::new(7));
        let rd = shared.clone();
        reg.counter_fn("huge2_adapted_total",
                       move || rd.load(Relaxed));
        reg.gauge_fn("huge2_depth", || -2);
        let s = reg.snapshot();
        assert_eq!(s.counters["huge2_test_total"], 3);
        assert_eq!(s.counters["huge2_adapted_total"], 7);
        assert_eq!(s.gauges["huge2_depth"], -2);
        // the same counter name returns the same atomic
        let c2 = reg.counter("huge2_test_total");
        c2.fetch_add(1, Relaxed);
        assert_eq!(c.load(Relaxed), 4);
    }

    #[test]
    fn snapshot_delta_subtracts_counters_and_histograms() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("huge2_done_total");
        let h = reg.histogram("huge2_lat_us");
        c.fetch_add(5, Relaxed);
        h.record(Duration::from_micros(50));
        let a = reg.snapshot();
        c.fetch_add(2, Relaxed);
        h.record(Duration::from_micros(7000));
        let b = reg.snapshot();
        let d = b.delta(&a);
        assert_eq!(d.counters["huge2_done_total"], 2);
        assert_eq!(d.histograms["huge2_lat_us"].count(), 1);
        assert!(d.histograms["huge2_lat_us"].quantile_us(0.5) >= 4096,
                "the window holds only the 7000µs sample");
    }

    #[test]
    fn merged_histogram_folds_label_series() {
        let reg = MetricsRegistry::new();
        reg.histogram("huge2_stage_reply_us{task=\"generate\"}")
            .record_us(10);
        reg.histogram("huge2_stage_reply_us{task=\"segment\"}")
            .record_us(30);
        reg.histogram("huge2_other_us").record_us(999);
        let s = reg.snapshot();
        let m = s.merged_histogram("huge2_stage_reply_us");
        assert_eq!(m.count(), 2);
        assert_eq!(m.max_us(), 30);
    }

    #[test]
    fn prometheus_text_shape() {
        let reg = MetricsRegistry::new();
        reg.counter("huge2_req_total").fetch_add(9, Relaxed);
        reg.gauge_fn("huge2_in_flight", || 1);
        reg.histogram("huge2_lat_us{task=\"generate\"}")
            .record_us(100);
        reg.histogram("huge2_lat_us{task=\"segment\"}").record_us(200);
        let text = reg.snapshot().to_prometheus();
        assert!(text.contains("# TYPE huge2_req_total counter"), "{text}");
        assert!(text.contains("huge2_req_total 9"), "{text}");
        assert!(text.contains("# TYPE huge2_in_flight gauge"), "{text}");
        assert!(text.contains(
            "huge2_lat_us{task=\"generate\",quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("huge2_lat_us_sum{task=\"generate\"} 100"),
                "{text}");
        assert!(text.contains("huge2_lat_us_count{task=\"segment\"} 1"),
                "{text}");
        // TYPE line appears once per base name even with two label sets
        let type_lines = text.matches("# TYPE huge2_lat_us summary")
            .count();
        assert_eq!(type_lines, 1, "{text}");
    }

    #[test]
    fn label_injection_and_suffixing() {
        assert_eq!(inject_label("m", "q=\"1\""), "m{q=\"1\"}");
        assert_eq!(inject_label("m{a=\"b\"}", "q=\"1\""),
                   "m{a=\"b\",q=\"1\"}");
        assert_eq!(suffix_name("m", "_sum"), "m_sum");
        assert_eq!(suffix_name("m{a=\"b\"}", "_count"),
                   "m_count{a=\"b\"}");
    }
}
