//! Engine metrics: log-bucketed latency histograms and throughput
//! counters (hand-rolled; no external metrics crates in the vendor set).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// HDR-style latency histogram: 64 log2 major buckets × 16 linear minor
/// buckets ⇒ ≤ ~6 % relative quantile error, O(1) record, lock-free.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const MINOR: usize = 16;
const MAJOR: usize = 40; // up to ~2^40 µs ≈ 12 days

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        let mut buckets = Vec::with_capacity(MAJOR * MINOR);
        buckets.resize_with(MAJOR * MINOR, || AtomicU64::new(0));
        Histogram {
            buckets,
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn index(us: u64) -> usize {
        if us < MINOR as u64 {
            return us as usize;
        }
        let major = 63 - us.leading_zeros() as usize; // floor(log2)
        let shift = major - 4; // keep top 4 bits after the leading 1
        let minor = ((us >> shift) & (MINOR as u64 - 1)) as usize;
        ((major - 3) * MINOR + minor).min(MAJOR * MINOR - 1)
    }

    /// Lower bound of a bucket (inverse of `index`).
    fn bucket_floor(idx: usize) -> u64 {
        if idx < MINOR {
            return idx as u64;
        }
        let major = idx / MINOR + 3;
        let minor = (idx % MINOR) as u64;
        (1u64 << major) | (minor << (major - 4))
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[Self::index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Quantile in µs (q ∈ [0,1]); bucket lower bound.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_floor(i);
            }
        }
        self.max_us()
    }

    /// "p50=…µs p95=…µs p99=…µs max=…µs (n=…)"
    pub fn summary(&self) -> String {
        format!(
            "p50={}µs p95={}µs p99={}µs max={}µs mean={:.0}µs (n={})",
            self.quantile_us(0.50),
            self.quantile_us(0.95),
            self.quantile_us(0.99),
            self.max_us(),
            self.mean_us(),
            self.count()
        )
    }
}

/// Monotonic event counters for the serving engine.
///
/// The **outcome-conservation invariant** (DESIGN.md §11): every call
/// to `Engine::submit` increments `submitted`, and every submitted
/// request terminates in exactly one of `rejected` (refused at submit:
/// validation, backpressure, shutdown), `completed` (a `Response` was
/// produced) or `failed` (a typed `ServeError` was delivered through
/// the reply channel). Once the engine is drained,
/// `submitted == completed + rejected + failed` — assertable, and
/// asserted by `tests/fault_stack.rs` under a fault-injection soak.
///
/// `dropped` and `panics` are telemetry, not outcome classes: a dropped
/// delivery still counted as completed/failed (the client hung up
/// before the outcome arrived), and a caught panic surfaces as `failed`
/// requests.
#[derive(Debug, Default)]
pub struct Counters {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    /// Requests that received a typed `ServeError` through their reply
    /// channel (gather validation, batch execution failure, worker
    /// panic).
    pub failed: AtomicU64,
    /// Terminal outcomes whose delivery failed because the client had
    /// already dropped its receiver. Subset telemetry: each is *also*
    /// counted in `completed` or `failed`.
    pub dropped: AtomicU64,
    /// Worker panics caught by batch supervision. Each panic fails its
    /// batch's remaining requests and the worker keeps draining — the
    /// pool never shrinks.
    pub panics: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// `submitted - (completed + rejected + failed)`: requests still in
    /// flight. Zero once the engine is drained — the conservation
    /// invariant in one number.
    pub fn in_flight(&self) -> i64 {
        let s = self.submitted.load(Ordering::Relaxed) as i64;
        let c = self.completed.load(Ordering::Relaxed) as i64;
        let r = self.rejected.load(Ordering::Relaxed) as i64;
        let f = self.failed.load(Ordering::Relaxed) as i64;
        s - (c + r + f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_ordered() {
        let h = Histogram::new();
        for us in 1..=1000u64 {
            h.record(Duration::from_micros(us));
        }
        let p50 = h.quantile_us(0.5);
        let p95 = h.quantile_us(0.95);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p95 && p95 <= p99 && p99 <= h.max_us());
        // log-bucket error ≤ ~6%
        assert!((p50 as f64 - 500.0).abs() / 500.0 < 0.10, "p50={p50}");
        assert!((p95 as f64 - 950.0).abs() / 950.0 < 0.10, "p95={p95}");
    }

    #[test]
    fn bucket_floor_inverts_index() {
        for us in [0u64, 5, 15, 16, 100, 1000, 123456, 10_000_000] {
            let idx = Histogram::index(us);
            let floor = Histogram::bucket_floor(idx);
            assert!(floor <= us, "floor({idx})={floor} > {us}");
            // next bucket's floor exceeds us
            if idx + 1 < MAJOR * MINOR {
                assert!(Histogram::bucket_floor(idx + 1) > us);
            }
        }
    }

    #[test]
    fn mean_and_count() {
        let h = Histogram::new();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.count(), 2);
        assert_eq!(h.mean_us(), 200.0);
        assert_eq!(h.max_us(), 300);
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn batch_counter() {
        let c = Counters::new();
        c.batches.fetch_add(2, Ordering::Relaxed);
        c.batched_requests.fetch_add(10, Ordering::Relaxed);
        assert_eq!(c.mean_batch_size(), 5.0);
    }

    #[test]
    fn in_flight_tracks_conservation() {
        let c = Counters::new();
        c.submitted.fetch_add(10, Ordering::Relaxed);
        c.completed.fetch_add(6, Ordering::Relaxed);
        c.rejected.fetch_add(2, Ordering::Relaxed);
        c.failed.fetch_add(1, Ordering::Relaxed);
        assert_eq!(c.in_flight(), 1);
        c.failed.fetch_add(1, Ordering::Relaxed);
        assert_eq!(c.in_flight(), 0, "drained ⇒ conservation holds");
    }
}
