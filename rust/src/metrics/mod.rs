//! Engine metrics: log-bucketed latency histograms and throughput
//! counters (hand-rolled; no external metrics crates in the vendor set).
//!
//! Submodules added by the observability layer (DESIGN.md §12):
//!
//! * [`span`] — request lifecycle stages and the per-(task, outcome)
//!   stage histograms (`queue_wait`, `batch_form`, `gather`, `forward`,
//!   `reply`);
//! * [`flight`] — the lock-free flight recorder ring of recent span
//!   events, dumped on worker panic;
//! * [`registry`] — the [`registry::MetricsRegistry`] snapshot /
//!   Prometheus-style exposition surface.

pub mod flight;
pub mod registry;
pub mod span;

pub use flight::{FlightEvent, FlightRecorder, Stage, SUBMIT_LANE};
pub use registry::{MetricsRegistry, MetricsSnapshot};
pub use span::{SpanOutcome, SpanStamps, StageMetrics};

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// HDR-style latency histogram: 64 log2 major buckets × 16 linear minor
/// buckets ⇒ ≤ ~6 % relative quantile error, O(1) record, lock-free.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const MINOR: usize = 16;
const MAJOR: usize = 40; // up to ~2^40 µs ≈ 12 days

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        let mut buckets = Vec::with_capacity(MAJOR * MINOR);
        buckets.resize_with(MAJOR * MINOR, || AtomicU64::new(0));
        Histogram {
            buckets,
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn index(us: u64) -> usize {
        if us < MINOR as u64 {
            return us as usize;
        }
        let major = 63 - us.leading_zeros() as usize; // floor(log2)
        let shift = major - 4; // keep top 4 bits after the leading 1
        let minor = ((us >> shift) & (MINOR as u64 - 1)) as usize;
        ((major - 3) * MINOR + minor).min(MAJOR * MINOR - 1)
    }

    /// Lower bound of a bucket (inverse of `index`).
    fn bucket_floor(idx: usize) -> u64 {
        if idx < MINOR {
            return idx as u64;
        }
        let major = idx / MINOR + 3;
        let minor = (idx % MINOR) as u64;
        (1u64 << major) | (minor << (major - 4))
    }

    pub fn record(&self, d: Duration) {
        // `as_micros` is u128; saturate rather than silently truncate a
        // pathological (> ~584 000 year) duration into a small value.
        self.record_us(u64::try_from(d.as_micros()).unwrap_or(u64::MAX));
    }

    /// Record a pre-converted µs sample.
    pub fn record_us(&self, us: u64) {
        self.buckets[Self::index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Add every sample of `other` into `self` (bucket-wise; `max`
    /// folded, `sum`/`count` added). Used to merge per-label series
    /// into one distribution for summary printing.
    pub fn merge(&self, other: &HistogramSnapshot) {
        for (b, &n) in self.buckets.iter().zip(&other.buckets) {
            if n > 0 {
                b.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count, Ordering::Relaxed);
        self.sum_us.fetch_add(other.sum_us, Ordering::Relaxed);
        self.max_us.fetch_max(other.max_us, Ordering::Relaxed);
    }

    /// Reset every bucket and counter to zero. Only meaningful while no
    /// recorder is concurrently writing (a racing `record_us` may land
    /// on either side of the clear).
    pub fn clear(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_us.store(0, Ordering::Relaxed);
        self.max_us.store(0, Ordering::Relaxed);
    }

    /// Point-in-time copy of the distribution. The copy is internally
    /// consistent: `count` is re-derived from the copied buckets, so a
    /// `record_us` racing the snapshot can at worst be missed entirely,
    /// never half-applied.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let buckets: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let count = buckets.iter().sum();
        HistogramSnapshot {
            buckets,
            count,
            sum_us: self.sum_us.load(Ordering::Relaxed),
            max_us: self.max_us.load(Ordering::Relaxed),
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Quantile in µs (q ∈ [0,1]); bucket lower bound.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_floor(i);
            }
        }
        self.max_us()
    }

    /// "p50=…µs p95=…µs p99=…µs max=…µs (n=…)"
    pub fn summary(&self) -> String {
        format!(
            "p50={}µs p95={}µs p99={}µs max={}µs mean={:.0}µs (n={})",
            self.quantile_us(0.50),
            self.quantile_us(0.95),
            self.quantile_us(0.99),
            self.max_us(),
            self.mean_us(),
            self.count()
        )
    }
}

/// An owned, point-in-time copy of a [`Histogram`] (same buckets, plain
/// `u64`s). Snapshots support the registry's delta-between-snapshots
/// operation and offline quantile queries without touching the live
/// atomics again.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        Self::empty()
    }
}

impl HistogramSnapshot {
    /// An all-zero snapshot (identity for [`HistogramSnapshot::merge`]).
    pub fn empty() -> Self {
        HistogramSnapshot {
            buckets: vec![0; MAJOR * MINOR],
            count: 0,
            sum_us: 0,
            max_us: 0,
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// Quantile in µs (q ∈ [0,1]); bucket lower bound — same walk as
    /// [`Histogram::quantile_us`].
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target =
            ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return Histogram::bucket_floor(i);
            }
        }
        self.max_us
    }

    /// "p50=…µs p95=…µs p99=…µs max=…µs mean=…µs (n=…)"
    pub fn summary(&self) -> String {
        format!(
            "p50={}µs p95={}µs p99={}µs max={}µs mean={:.0}µs (n={})",
            self.quantile_us(0.50),
            self.quantile_us(0.95),
            self.quantile_us(0.99),
            self.max_us,
            self.mean_us(),
            self.count
        )
    }

    /// Fold another snapshot into this one (bucket-wise add).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (b, &n) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += n;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Decompose into the explicit wire form trace checkpoints embed
    /// (DESIGN.md §13): the non-zero `(bucket_index, count)` pairs in
    /// index order, plus `sum_us` and `max_us`. `count` is not part of
    /// the wire form — it is re-derived on decode, the same way
    /// [`Histogram::snapshot`] re-derives it, so a checkpoint can never
    /// carry an internally inconsistent distribution.
    pub fn to_sparse(&self) -> (Vec<(usize, u64)>, u64, u64) {
        let pairs: Vec<(usize, u64)> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(i, &n)| (i, n))
            .collect();
        (pairs, self.sum_us, self.max_us)
    }

    /// Rebuild a snapshot from its sparse wire form. Rejects
    /// out-of-range bucket indices (a corrupt checkpoint must error,
    /// not panic or silently mis-bucket).
    pub fn from_sparse(pairs: &[(usize, u64)], sum_us: u64, max_us: u64)
                       -> Result<Self, String> {
        let mut buckets = vec![0u64; MAJOR * MINOR];
        for &(idx, n) in pairs {
            if idx >= MAJOR * MINOR {
                return Err(format!(
                    "histogram bucket index {idx} out of range \
                     (max {})",
                    MAJOR * MINOR - 1
                ));
            }
            buckets[idx] += n;
        }
        let count = buckets.iter().sum();
        Ok(HistogramSnapshot { buckets, count, sum_us, max_us })
    }

    /// The samples recorded *since* `earlier` (bucket-wise saturating
    /// subtraction; `earlier` must be an older snapshot of the same
    /// histogram). `max_us` is kept from `self` — the true
    /// window-maximum is not recoverable from two cumulative maxima, so
    /// the delta's max is an upper bound.
    pub fn delta_since(&self, earlier: &HistogramSnapshot) -> Self {
        let buckets: Vec<u64> = self
            .buckets
            .iter()
            .zip(&earlier.buckets)
            .map(|(&a, &b)| a.saturating_sub(b))
            .collect();
        let count = buckets.iter().sum();
        HistogramSnapshot {
            buckets,
            count,
            sum_us: self.sum_us.saturating_sub(earlier.sum_us),
            max_us: self.max_us,
        }
    }
}

/// Monotonic event counters for the serving engine.
///
/// The **outcome-conservation invariant** (DESIGN.md §11): every call
/// to `Engine::submit` increments `submitted`, and every submitted
/// request terminates in exactly one of `rejected` (refused at submit:
/// validation, backpressure, shutdown), `completed` (a `Response` was
/// produced) or `failed` (a typed `ServeError` was delivered through
/// the reply channel). Once the engine is drained,
/// `submitted == completed + rejected + failed` — assertable, and
/// asserted by `tests/fault_stack.rs` under a fault-injection soak.
///
/// `dropped` and `panics` are telemetry, not outcome classes: a dropped
/// delivery still counted as completed/failed (the client hung up
/// before the outcome arrived), and a caught panic surfaces as `failed`
/// requests.
#[derive(Debug, Default)]
pub struct Counters {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    /// Requests that received a typed `ServeError` through their reply
    /// channel (gather validation, batch execution failure, worker
    /// panic).
    pub failed: AtomicU64,
    /// Terminal outcomes whose delivery failed because the client had
    /// already dropped its receiver. Subset telemetry: each is *also*
    /// counted in `completed` or `failed`.
    pub dropped: AtomicU64,
    /// Worker panics caught by batch supervision. Each panic fails its
    /// batch's remaining requests and the worker keeps draining — the
    /// pool never shrinks.
    pub panics: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Requests refused (or displaced from the queue) by the
    /// priority-aware admission controller under load (DESIGN.md §16).
    /// Subset telemetry: every shed is *also* counted in `rejected` —
    /// conservation is unchanged.
    pub shed: AtomicU64,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// `submitted - (completed + rejected + failed)`: requests still in
    /// flight. Zero once the engine is drained — the conservation
    /// invariant in one number.
    pub fn in_flight(&self) -> i64 {
        let s = self.submitted.load(Ordering::Relaxed) as i64;
        let c = self.completed.load(Ordering::Relaxed) as i64;
        let r = self.rejected.load(Ordering::Relaxed) as i64;
        let f = self.failed.load(Ordering::Relaxed) as i64;
        s - (c + r + f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_ordered() {
        let h = Histogram::new();
        for us in 1..=1000u64 {
            h.record(Duration::from_micros(us));
        }
        let p50 = h.quantile_us(0.5);
        let p95 = h.quantile_us(0.95);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p95 && p95 <= p99 && p99 <= h.max_us());
        // log-bucket error ≤ ~6%
        assert!((p50 as f64 - 500.0).abs() / 500.0 < 0.10, "p50={p50}");
        assert!((p95 as f64 - 950.0).abs() / 950.0 < 0.10, "p95={p95}");
    }

    #[test]
    fn bucket_floor_inverts_index() {
        for us in [0u64, 5, 15, 16, 100, 1000, 123456, 10_000_000] {
            let idx = Histogram::index(us);
            let floor = Histogram::bucket_floor(idx);
            assert!(floor <= us, "floor({idx})={floor} > {us}");
            // next bucket's floor exceeds us
            if idx + 1 < MAJOR * MINOR {
                assert!(Histogram::bucket_floor(idx + 1) > us);
            }
        }
    }

    #[test]
    fn mean_and_count() {
        let h = Histogram::new();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.count(), 2);
        assert_eq!(h.mean_us(), 200.0);
        assert_eq!(h.max_us(), 300);
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn record_saturates_oversized_durations() {
        let h = Histogram::new();
        // > u64::MAX µs — must land in the top bucket, not wrap small
        h.record(Duration::from_secs(u64::MAX));
        assert_eq!(h.count(), 1);
        assert_eq!(h.max_us(), u64::MAX);
        assert!(h.quantile_us(0.5) > 1u64 << 39,
                "saturated sample must sit in the top buckets");
    }

    #[test]
    fn snapshot_matches_live_histogram() {
        let h = Histogram::new();
        for us in [3u64, 40, 500, 6000] {
            h.record_us(us);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), h.count());
        assert_eq!(s.max_us(), h.max_us());
        assert_eq!(s.mean_us(), h.mean_us());
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(s.quantile_us(q), h.quantile_us(q));
        }
        assert_eq!(s.summary(), h.summary());
    }

    #[test]
    fn merge_folds_distributions() {
        let a = Histogram::new();
        let b = Histogram::new();
        for us in 1..=100u64 {
            a.record_us(us);
        }
        for us in 901..=1000u64 {
            b.record_us(us);
        }
        a.merge(&b.snapshot());
        assert_eq!(a.count(), 200);
        assert_eq!(a.max_us(), 1000);
        let p50 = a.quantile_us(0.5);
        assert!(p50 <= 100, "lower half must stay low, p50={p50}");
        let p99 = a.quantile_us(0.99);
        assert!(p99 >= 900, "upper tail must come from b, p99={p99}");
    }

    #[test]
    fn clear_resets_everything() {
        let h = Histogram::new();
        h.record_us(123);
        h.clear();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max_us(), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.snapshot(), HistogramSnapshot::empty());
    }

    #[test]
    fn snapshot_delta_isolates_a_window() {
        let h = Histogram::new();
        h.record_us(10);
        h.record_us(20);
        let before = h.snapshot();
        h.record_us(5000);
        let after = h.snapshot();
        let d = after.delta_since(&before);
        assert_eq!(d.count(), 1);
        assert_eq!(d.sum_us(), 5000);
        assert!(d.quantile_us(0.5) >= 4096, "window holds only 5000µs");
        // merging the window back re-creates the cumulative snapshot
        let mut rebuilt = before.clone();
        rebuilt.merge(&d);
        assert_eq!(rebuilt.count(), after.count());
        assert_eq!(rebuilt.sum_us(), after.sum_us());
    }

    #[test]
    fn sparse_form_round_trips_exactly() {
        let h = Histogram::new();
        for us in [0u64, 3, 40, 500, 6000, 6001, u64::MAX] {
            h.record_us(us);
        }
        let s = h.snapshot();
        let (pairs, sum_us, max_us) = s.to_sparse();
        assert!(pairs.len() <= 7, "sparse form stores only hit buckets");
        assert!(pairs.windows(2).all(|w| w[0].0 < w[1].0));
        let back =
            HistogramSnapshot::from_sparse(&pairs, sum_us, max_us)
                .unwrap();
        assert_eq!(back, s);
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(back.quantile_us(q), s.quantile_us(q));
        }
        // empty snapshot ⇒ empty sparse form
        let (pairs, sum, max) = HistogramSnapshot::empty().to_sparse();
        assert!(pairs.is_empty());
        let empty =
            HistogramSnapshot::from_sparse(&pairs, sum, max).unwrap();
        assert_eq!(empty, HistogramSnapshot::empty());
        // out-of-range bucket index is a decode error, not a panic
        assert!(HistogramSnapshot::from_sparse(
            &[(MAJOR * MINOR, 1)], 0, 0).is_err());
    }

    #[test]
    fn batch_counter() {
        let c = Counters::new();
        c.batches.fetch_add(2, Ordering::Relaxed);
        c.batched_requests.fetch_add(10, Ordering::Relaxed);
        assert_eq!(c.mean_batch_size(), 5.0);
    }

    #[test]
    fn in_flight_tracks_conservation() {
        let c = Counters::new();
        c.submitted.fetch_add(10, Ordering::Relaxed);
        c.completed.fetch_add(6, Ordering::Relaxed);
        c.rejected.fetch_add(2, Ordering::Relaxed);
        c.failed.fetch_add(1, Ordering::Relaxed);
        assert_eq!(c.in_flight(), 1);
        c.failed.fetch_add(1, Ordering::Relaxed);
        assert_eq!(c.in_flight(), 0, "drained ⇒ conservation holds");
    }
}
