//! Deterministic PRNG substrate.
//!
//! The vendored crate set has no `rand`, so the engine carries its own
//! generator: SplitMix64 (Steele et al. 2014) — a tiny, high-quality,
//! splittable 64-bit generator. It seeds synthetic weights, latents,
//! workload traces and the property-based tests, so every experiment in
//! EXPERIMENTS.md is bit-reproducible.

/// SplitMix64 PRNG with Box–Muller normal sampling.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second output of the last Box–Muller transform.
    spare: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed, spare: None }
    }

    /// Derive an independent stream (for per-thread / per-request rngs).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0x9e37_79b9_7f4a_7c15)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f32 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u1 = self.next_f32();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.next_f32();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f32::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Exponential with rate `lambda` (inter-arrival times of the Poisson
    /// open-loop workload generator).
    pub fn next_exp(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::new(42);
        let mut c = a.split();
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.next_exp(4.0)).sum::<f64>() / n as f64;
        assert!((m - 0.25).abs() < 0.01, "mean {m}");
    }
}
