//! Synthetic request-workload generators for the serving benches:
//! open-loop Poisson arrivals (edge cameras / interactive clients) and
//! closed-loop saturation (the paper's "throughput" setting).
//!
//! Arrival traces are also **replayable fixtures**: [`save`] / [`load`]
//! round-trip a trace through a tiny text format (`<offset_ns> <id>`
//! lines), so a synthetic workload generated once — or captured from a
//! live run — can be re-driven bit-identically by `huge2 serve
//! --arrivals f` or fed to the record/replay subsystem
//! ([`crate::replay`]).

use anyhow::{anyhow, bail, Context, Result};
use crate::rng::Rng;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;
use std::time::Duration;

/// One generation request in a workload trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Offset from trace start.
    pub at: Duration,
    /// Request id (dense, 0-based).
    pub id: u64,
}

/// Open-loop Poisson arrival process at `rate_hz`, `n` requests.
pub fn poisson(rate_hz: f64, n: usize, seed: u64) -> Vec<Arrival> {
    assert!(rate_hz > 0.0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..n as u64)
        .map(|id| {
            t += rng.next_exp(rate_hz);
            Arrival { at: Duration::from_secs_f64(t), id }
        })
        .collect()
}

/// Deterministic uniform arrivals (one every `1/rate_hz`).
pub fn uniform(rate_hz: f64, n: usize) -> Vec<Arrival> {
    assert!(rate_hz > 0.0);
    let dt = 1.0 / rate_hz;
    (0..n as u64)
        .map(|id| Arrival {
            at: Duration::from_secs_f64(dt * (id + 1) as f64),
            id,
        })
        .collect()
}

/// Bursty arrivals: bursts of `burst` back-to-back requests with Poisson
/// gaps between bursts — stresses the dynamic batcher's deadline logic.
pub fn bursty(burst: usize, gap_hz: f64, n: usize, seed: u64)
              -> Vec<Arrival> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    let mut id = 0u64;
    while out.len() < n {
        t += rng.next_exp(gap_hz);
        for _ in 0..burst {
            if out.len() == n {
                break;
            }
            out.push(Arrival { at: Duration::from_secs_f64(t), id });
            id += 1;
        }
    }
    out
}

/// Save an arrival trace as a replayable fixture: one `<offset_ns> <id>`
/// line per request (ns so the round-trip is exact), `#` comments.
pub fn save(path: &Path, arrivals: &[Arrival]) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# huge2 arrival trace v1: <offset_ns> <id>")?;
    for a in arrivals {
        writeln!(w, "{} {}", a.at.as_nanos(), a.id)?;
    }
    w.flush()?;
    Ok(())
}

/// Load an arrival-trace fixture written by [`save`]. Rejects malformed
/// lines and non-monotone offsets (a corrupted fixture should fail
/// loudly, not skew a benchmark silently).
pub fn load(path: &Path) -> Result<Vec<Arrival>> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let reader = BufReader::new(file);
    let mut out: Vec<Arrival> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line
            .with_context(|| format!("reading {}", path.display()))?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let bad = || {
            anyhow!("{}:{}: expected '<offset_ns> <id>', got {line:?}",
                    path.display(), lineno + 1)
        };
        let ns: u64 = parts
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(&bad)?;
        let id: u64 = parts
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(&bad)?;
        if parts.next().is_some() {
            return Err(bad());
        }
        let at = Duration::from_nanos(ns);
        if let Some(prev) = out.last() {
            if prev.at > at {
                bail!("{}:{}: offsets must be monotone non-decreasing",
                      path.display(), lineno + 1);
            }
        }
        out.push(Arrival { at, id });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate() {
        let tr = poisson(100.0, 20_000, 7);
        let span = tr.last().unwrap().at.as_secs_f64();
        let rate = tr.len() as f64 / span;
        assert!((rate - 100.0).abs() < 5.0, "rate {rate}");
    }

    #[test]
    fn arrivals_monotone() {
        for tr in [poisson(50.0, 1000, 1), uniform(50.0, 1000),
                   bursty(8, 10.0, 1000, 2)] {
            for w in tr.windows(2) {
                assert!(w[0].at <= w[1].at);
                assert_eq!(w[0].id + 1, w[1].id);
            }
        }
    }

    #[test]
    fn bursty_groups() {
        let tr = bursty(4, 10.0, 40, 3);
        // every burst of 4 shares a timestamp
        for chunk in tr.chunks(4) {
            assert!(chunk.iter().all(|a| a.at == chunk[0].at));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        assert_eq!(poisson(10.0, 100, 5), poisson(10.0, 100, 5));
        assert_ne!(poisson(10.0, 100, 5), poisson(10.0, 100, 6));
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("huge2_trace_{}_{name}", std::process::id()))
    }

    #[test]
    fn save_load_round_trip_is_exact() {
        for (i, tr) in [poisson(50.0, 200, 1), uniform(50.0, 64),
                        bursty(8, 10.0, 100, 2)]
            .into_iter()
            .enumerate()
        {
            let path = tmp(&format!("rt{i}.txt"));
            save(&path, &tr).unwrap();
            let back = load(&path).unwrap();
            std::fs::remove_file(&path).ok();
            assert_eq!(back, tr);
        }
    }

    #[test]
    fn load_rejects_corruption() {
        let path = tmp("bad.txt");
        std::fs::write(&path, "# c\n10 0\nnot a line\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "20 0\n10 1\n").unwrap();
        assert!(load(&path).is_err(), "non-monotone offsets rejected");
        std::fs::write(&path, "10 0 junk\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
        assert!(load(&path).is_err(), "missing file is an error");
    }
}
