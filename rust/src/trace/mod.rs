//! Synthetic request-workload generators for the serving benches:
//! open-loop Poisson arrivals (edge cameras / interactive clients) and
//! closed-loop saturation (the paper's "throughput" setting).

use crate::rng::Rng;
use std::time::Duration;

/// One generation request in a workload trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Offset from trace start.
    pub at: Duration,
    /// Request id (dense, 0-based).
    pub id: u64,
}

/// Open-loop Poisson arrival process at `rate_hz`, `n` requests.
pub fn poisson(rate_hz: f64, n: usize, seed: u64) -> Vec<Arrival> {
    assert!(rate_hz > 0.0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..n as u64)
        .map(|id| {
            t += rng.next_exp(rate_hz);
            Arrival { at: Duration::from_secs_f64(t), id }
        })
        .collect()
}

/// Deterministic uniform arrivals (one every `1/rate_hz`).
pub fn uniform(rate_hz: f64, n: usize) -> Vec<Arrival> {
    assert!(rate_hz > 0.0);
    let dt = 1.0 / rate_hz;
    (0..n as u64)
        .map(|id| Arrival {
            at: Duration::from_secs_f64(dt * (id + 1) as f64),
            id,
        })
        .collect()
}

/// Bursty arrivals: bursts of `burst` back-to-back requests with Poisson
/// gaps between bursts — stresses the dynamic batcher's deadline logic.
pub fn bursty(burst: usize, gap_hz: f64, n: usize, seed: u64)
              -> Vec<Arrival> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    let mut id = 0u64;
    while out.len() < n {
        t += rng.next_exp(gap_hz);
        for _ in 0..burst {
            if out.len() == n {
                break;
            }
            out.push(Arrival { at: Duration::from_secs_f64(t), id });
            id += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate() {
        let tr = poisson(100.0, 20_000, 7);
        let span = tr.last().unwrap().at.as_secs_f64();
        let rate = tr.len() as f64 / span;
        assert!((rate - 100.0).abs() < 5.0, "rate {rate}");
    }

    #[test]
    fn arrivals_monotone() {
        for tr in [poisson(50.0, 1000, 1), uniform(50.0, 1000),
                   bursty(8, 10.0, 1000, 2)] {
            for w in tr.windows(2) {
                assert!(w[0].at <= w[1].at);
                assert_eq!(w[0].id + 1, w[1].id);
            }
        }
    }

    #[test]
    fn bursty_groups() {
        let tr = bursty(4, 10.0, 40, 3);
        // every burst of 4 shares a timestamp
        for chunk in tr.chunks(4) {
            assert!(chunk.iter().all(|a| a.at == chunk[0].at));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        assert_eq!(poisson(10.0, 100, 5), poisson(10.0, 100, 5));
        assert_ne!(poisson(10.0, 100, 5), poisson(10.0, 100, 6));
    }
}
