//! Artifact loading + PJRT execution (the L3 ↔ L2/L1 bridge).
//!
//! * [`artifact`] — manifest of the AOT entry points emitted by
//!   `python/compile/aot.py` (names, files, input/output specs).
//! * [`pjrt`] — compile HLO text on the PJRT CPU client and execute it
//!   with [`crate::tensor::Tensor`] inputs/outputs.

pub mod artifact;
pub mod pjrt;
pub mod service;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use pjrt::{Executable, Runtime};
pub use service::RuntimeHandle;
