//! Artifact loading + PJRT execution (the L3 ↔ L2/L1 bridge).
//!
//! * [`artifact`] — manifest of the AOT entry points emitted by
//!   `python/compile/aot.py` (names, files, input/output specs).
//! * [`pjrt`] — compile HLO text on the PJRT CPU client and execute it
//!   with [`crate::tensor::Tensor`] inputs/outputs.

pub mod artifact;
/// Real PJRT bridge — needs the vendored `xla` crate (features
/// `pjrt` + `xla` together).
#[cfg(all(feature = "pjrt", feature = "xla"))]
pub mod pjrt;
/// Same public surface, no `xla` dependency: every execution attempt
/// fails with an actionable error. Compiled whenever the real binding
/// isn't — including `--features pjrt` alone, which CI uses as a
/// no-native-deps compile check of the feature surface.
#[cfg(not(all(feature = "pjrt", feature = "xla")))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;
pub mod service;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use pjrt::{Executable, Runtime};
pub use service::RuntimeHandle;
