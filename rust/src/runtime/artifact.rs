//! AOT-artifact manifest: the contract between `python/compile/aot.py`
//! and the Rust runtime.
//!
//! Format (one record per compiled entry point):
//!
//! ```text
//! artifact <name> <file>
//! input 0 float32 1,4,4,1024
//! input 1 float32 5,5,1024,512
//! output 0 float32 1,8,8,512
//! end
//! ```

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One compiled entry point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest of an artifact directory.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    by_name: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut by_name = HashMap::new();
        let mut cur: Option<ArtifactSpec> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let tag = parts.next().unwrap();
            let err = |m: &str| anyhow!("manifest line {}: {m}", lineno + 1);
            match tag {
                "artifact" => {
                    if cur.is_some() {
                        bail!(err("nested artifact (missing 'end')"));
                    }
                    let name = parts.next().ok_or_else(|| err("name"))?;
                    let file = parts.next().ok_or_else(|| err("file"))?;
                    cur = Some(ArtifactSpec {
                        name: name.to_string(),
                        file: dir.join(file),
                        inputs: vec![],
                        outputs: vec![],
                    });
                }
                "input" | "output" => {
                    let spec = cur.as_mut()
                        .ok_or_else(|| err("io outside artifact"))?;
                    let idx: usize = parts
                        .next().ok_or_else(|| err("index"))?
                        .parse().map_err(|_| err("bad index"))?;
                    let dtype = parts.next().ok_or_else(|| err("dtype"))?;
                    let dims_s = parts.next().ok_or_else(|| err("dims"))?;
                    let dims: Vec<usize> = if dims_s == "scalar" {
                        vec![]
                    } else {
                        dims_s
                            .split(',')
                            .map(|d| d.parse()
                                 .map_err(|_| err("bad dim")))
                            .collect::<Result<_>>()?
                    };
                    let ts = TensorSpec { dtype: dtype.to_string(), dims };
                    let list = if tag == "input" {
                        &mut spec.inputs
                    } else {
                        &mut spec.outputs
                    };
                    if idx != list.len() {
                        bail!(err("out-of-order io index"));
                    }
                    list.push(ts);
                }
                "end" => {
                    let spec = cur.take()
                        .ok_or_else(|| err("end outside artifact"))?;
                    by_name.insert(spec.name.clone(), spec);
                }
                other => bail!(err(&format!("unknown tag {other:?}"))),
            }
        }
        if cur.is_some() {
            bail!("manifest truncated (missing final 'end')");
        }
        Ok(Manifest { by_name })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.by_name
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest \
                                    (available: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> =
            self.by_name.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact demo demo.hlo.txt
input 0 float32 1,4,4,8
input 1 float32 5,5,8,4
output 0 float32 1,8,8,4
end
artifact scalar_out s.hlo.txt
output 0 float32 scalar
end
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.len(), 2);
        let d = m.get("demo").unwrap();
        assert_eq!(d.inputs.len(), 2);
        assert_eq!(d.inputs[0].dims, vec![1, 4, 4, 8]);
        assert_eq!(d.inputs[0].elements(), 128);
        assert_eq!(d.file, Path::new("/a/demo.hlo.txt"));
        let s = m.get("scalar_out").unwrap();
        assert_eq!(s.outputs[0].dims, Vec::<usize>::new());
        assert_eq!(s.outputs[0].elements(), 1);
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "input 0 float32 1,2\n",
            "artifact a f\ninput 1 float32 1\nend\n",
            "artifact a f\n",
            "artifact a f\nartifact b g\nend\n",
            "bogus\n",
        ] {
            assert!(Manifest::parse(bad, Path::new("/")).is_err(), "{bad}");
        }
    }

    #[test]
    fn missing_artifact_error_lists_names() {
        let m = Manifest::parse(SAMPLE, Path::new("/")).unwrap();
        let e = m.get("nope").unwrap_err().to_string();
        assert!(e.contains("demo"));
    }

    #[test]
    fn real_manifest_loads() {
        // integration: parse the manifest actually emitted by aot.py if
        // artifacts were built
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.get("dcgan_dc1_huge2").is_ok());
            let g = m.get("dcgan_gen_b1").unwrap();
            assert_eq!(g.inputs[0].dims, vec![1, 100]);
            assert_eq!(g.outputs[0].dims, vec![1, 64, 64, 3]);
        }
    }
}
