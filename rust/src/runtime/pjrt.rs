//! PJRT execution of AOT artifacts — the bridge from the Rust coordinator
//! to the JAX/Pallas-compiled HLO (via the `xla` crate's PJRT C API).
//!
//! Pattern (from /opt/xla-example/load_hlo): HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `client.compile`
//! → `execute`. Text is the interchange format because xla_extension
//! 0.5.1 rejects jax≥0.5's 64-bit-id serialized protos.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::tensor::Tensor;

use super::artifact::{ArtifactSpec, Manifest};

/// A compiled entry point ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Run with NHWC/row-major f32 tensors; returns one tensor per output.
    ///
    /// Inputs are validated against the manifest (count + element count)
    /// before they touch the runtime, so shape bugs fail with a useful
    /// message instead of an XLA internal error.
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!("{}: expected {} inputs, got {}", self.spec.name,
                  self.spec.inputs.len(), inputs.len());
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (i, (t, ts)) in inputs.iter().zip(&self.spec.inputs).enumerate()
        {
            if t.len() != ts.elements() {
                bail!("{}: input {i} has {} elements, manifest says {:?}",
                      self.spec.name, t.len(), ts.dims);
            }
            let dims: Vec<i64> =
                ts.dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(t.data()).reshape(&dims)?;
            lits.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always unwrap a tuple.
        let outs = result.to_tuple()?;
        if outs.len() != self.spec.outputs.len() {
            bail!("{}: runtime returned {} outputs, manifest says {}",
                  self.spec.name, outs.len(), self.spec.outputs.len());
        }
        let mut tensors = Vec::with_capacity(outs.len());
        for (lit, ts) in outs.iter().zip(&self.spec.outputs) {
            let v = lit.to_vec::<f32>()?;
            let dims = if ts.dims.is_empty() {
                vec![1]
            } else {
                ts.dims.clone()
            };
            tensors.push(Tensor::from_vec(&dims, v));
        }
        Ok(tensors)
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }
}

/// The runtime: one PJRT CPU client + lazily compiled, cached executables.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Open an artifact directory (must contain `manifest.txt`).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) one artifact by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .with_context(|| format!("parsing {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let arc = std::sync::Arc::new(Executable { spec, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), arc.clone());
        Ok(arc)
    }

    /// Convenience: load + run in one call.
    pub fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?.run(inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::layer_by_name;
    use crate::deconv::baseline;
    use crate::rng::Rng;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.txt").exists()
    }

    /// The cross-layer correctness keystone: the AOT-compiled Pallas
    /// HUGE² kernel and the pure-Rust engines agree on a Table-1 layer.
    #[test]
    fn pjrt_layer_matches_rust_engines() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::open(&artifacts_dir()).unwrap();
        let layer = layer_by_name("cgan_dc2").unwrap();
        let mut rng = Rng::new(77);
        let x = Tensor::randn(&[1, layer.h, layer.h, layer.c_in], &mut rng);
        let k = Tensor::randn(&[layer.k, layer.k, layer.c_in, layer.c_out],
                              &mut rng).scale(0.05);
        let got_pallas = rt.run("cgan_dc2_huge2", &[&x, &k]).unwrap();
        let got_base = rt.run("cgan_dc2_baseline", &[&x, &k]).unwrap();
        let want = baseline::conv2d_transpose(&x, &k, &layer.deconv_params());
        assert_eq!(got_pallas[0].shape(), want.shape());
        assert!(got_pallas[0].allclose(&want, 1e-3),
                "pallas vs rust: {}", got_pallas[0].max_abs_diff(&want));
        assert!(got_base[0].allclose(&want, 1e-3),
                "jax-baseline vs rust: {}", got_base[0].max_abs_diff(&want));
    }

    #[test]
    fn rejects_wrong_input_count_and_shape() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::open(&artifacts_dir()).unwrap();
        let exe = rt.load("cgan_dc2_huge2").unwrap();
        let x = Tensor::zeros(&[1, 16, 16, 128]);
        assert!(exe.run(&[&x]).is_err()); // missing kernel input
        let bad = Tensor::zeros(&[1, 2, 2, 1]);
        let k = Tensor::zeros(&[4, 4, 128, 3]);
        assert!(exe.run(&[&bad, &k]).is_err());
    }

    #[test]
    fn executables_are_cached() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::open(&artifacts_dir()).unwrap();
        let a = rt.load("cgan_dc2_huge2").unwrap();
        let b = rt.load("cgan_dc2_huge2").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::open(&artifacts_dir()).unwrap();
        assert!(rt.load("does_not_exist").is_err());
    }
}
