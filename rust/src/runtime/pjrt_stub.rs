//! Stub PJRT runtime, compiled unless the `pjrt` **and** `xla` cargo
//! features are both on.
//!
//! The real implementation (`pjrt.rs`) needs the vendored `xla` crate
//! (PJRT C API + `xla_extension` shared library), which not every build
//! environment carries. This stub keeps the whole crate — native serving,
//! record/replay, benches, tests — compiling and working everywhere:
//! it mirrors the public surface of [`Runtime`]/[`Executable`] exactly,
//! still validates the artifact directory (so error ordering matches the
//! real path), and fails `open` with an actionable message instead of a
//! linker error at build time. `cargo check --features pjrt` (CI) builds
//! this stub, so the feature flag itself can never rot.

use anyhow::{bail, Result};
use std::path::Path;
use std::sync::Arc;

use crate::tensor::Tensor;

use super::artifact::{ArtifactSpec, Manifest};

const NO_PJRT: &str =
    "PJRT execution is not compiled into this build (it needs the cargo \
     features `pjrt,xla` plus the vendored `xla` crate). Serve with \
     --native, or rebuild with `cargo build --features pjrt,xla`.";

/// Stub of the compiled-artifact handle. Never constructible (the stub
/// [`Runtime::open`] always fails), but keeps dependents well-typed.
pub struct Executable {
    pub spec: ArtifactSpec,
}

impl Executable {
    pub fn run(&self, _inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        bail!(NO_PJRT)
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }
}

/// Stub of the PJRT runtime: same API as `pjrt::Runtime`, always errors.
pub struct Runtime {
    manifest: Manifest,
}

impl Runtime {
    /// Validates the artifact directory (same error ordering as the real
    /// runtime), then reports that PJRT support is compiled out.
    pub fn open(dir: &Path) -> Result<Self> {
        let _manifest = Manifest::load(dir)?;
        bail!(NO_PJRT)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "stub (pjrt feature off)".to_string()
    }

    pub fn load(&self, _name: &str) -> Result<Arc<Executable>> {
        bail!(NO_PJRT)
    }

    pub fn run(&self, _name: &str, _inputs: &[&Tensor])
               -> Result<Vec<Tensor>> {
        bail!(NO_PJRT)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_reports_missing_feature() {
        // nonexistent dir: manifest load fails first, like the real path
        let err = Runtime::open(Path::new("/nonexistent/artifacts"))
            .unwrap_err();
        assert!(!err.to_string().is_empty());
        // existing dir with a manifest would hit the feature error; we
        // can't fabricate one here without artifacts, so just check the
        // message constant is wired.
        assert!(NO_PJRT.contains("--native"));
    }
}
