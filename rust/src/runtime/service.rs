//! Runtime service thread: the PJRT client behind a `Send + Sync` handle.
//!
//! The `xla` crate's client/executable types hold `Rc`s and raw pointers
//! (not `Send`), so the engine runs ONE dedicated runtime thread that owns
//! the [`Runtime`] and serves execution jobs over a channel. This also
//! serialises device access — the CPU PJRT client parallelises *inside* an
//! execution, so a single submission thread is the throughput-optimal
//! topology (and matches how a real TPU/edge accelerator is driven).
//!
//! Model weights are **bound once** (`bind`) and stay resident in the
//! service thread, so a per-batch job ships only the latents — the
//! multi-megabyte weight tensors never cross the channel after load.

use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Mutex;

use crate::tensor::Tensor;

use super::artifact::Manifest;
use super::pjrt::Runtime;

enum Job {
    /// Execute `name` with `inputs` (+ weights bound under `bound_key`).
    Run {
        name: String,
        inputs: Vec<Tensor>,
        bound_key: Option<String>,
        reply: mpsc::Sender<Result<Vec<Tensor>>>,
    },
    /// Store weights under a key, resident in the service thread.
    Bind {
        key: String,
        weights: Vec<Tensor>,
        reply: mpsc::Sender<Result<()>>,
    },
    /// Pre-compile an artifact (warmup).
    Warm { name: String, reply: mpsc::Sender<Result<()>> },
    Shutdown,
}

/// Cloneable, thread-safe handle to the runtime service.
pub struct RuntimeHandle {
    tx: Mutex<mpsc::Sender<Job>>,
    manifest: Manifest,
}

impl RuntimeHandle {
    /// Start the service thread on an artifact directory.
    pub fn spawn(dir: PathBuf) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let (tx, rx) = mpsc::channel::<Job>();
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || {
                let rt = match Runtime::open(&dir) {
                    Ok(rt) => {
                        let _ = init_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                let mut bound: HashMap<String, Vec<Tensor>> = HashMap::new();
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Run { name, inputs, bound_key, reply } => {
                            let res = (|| {
                                let mut refs: Vec<&Tensor> =
                                    inputs.iter().collect();
                                if let Some(key) = &bound_key {
                                    let w = bound.get(key).ok_or_else(|| {
                                        anyhow!("no weights bound as \
                                                 {key:?}")
                                    })?;
                                    refs.extend(w.iter());
                                }
                                rt.run(&name, &refs)
                            })();
                            let _ = reply.send(res);
                        }
                        Job::Bind { key, weights, reply } => {
                            bound.insert(key, weights);
                            let _ = reply.send(Ok(()));
                        }
                        Job::Warm { name, reply } => {
                            let _ = reply.send(rt.load(&name).map(|_| ()));
                        }
                        Job::Shutdown => break,
                    }
                }
            })?;
        init_rx
            .recv()
            .map_err(|_| anyhow!("runtime thread died during init"))??;
        Ok(RuntimeHandle { tx: Mutex::new(tx), manifest })
    }

    fn send(&self, job: Job) -> Result<()> {
        self.tx
            .lock()
            .unwrap()
            .send(job)
            .map_err(|_| anyhow!("runtime service stopped"))
    }

    /// Execute an artifact with explicit inputs.
    pub fn run(&self, name: &str, inputs: Vec<Tensor>)
               -> Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.send(Job::Run {
            name: name.into(),
            inputs,
            bound_key: None,
            reply,
        })?;
        rx.recv().map_err(|_| anyhow!("runtime service stopped"))?
    }

    /// Execute with `inputs` followed by the weights bound under `key`.
    pub fn run_bound(&self, name: &str, inputs: Vec<Tensor>, key: &str)
                     -> Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.send(Job::Run {
            name: name.into(),
            inputs,
            bound_key: Some(key.into()),
            reply,
        })?;
        rx.recv().map_err(|_| anyhow!("runtime service stopped"))?
    }

    /// Make weights resident in the service thread under `key`.
    pub fn bind(&self, key: &str, weights: Vec<Tensor>) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.send(Job::Bind { key: key.into(), weights, reply })?;
        rx.recv().map_err(|_| anyhow!("runtime service stopped"))?
    }

    /// Pre-compile an artifact so first-request latency excludes XLA
    /// compilation.
    pub fn warm(&self, name: &str) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.send(Job::Warm { name: name.into(), reply })?;
        rx.recv().map_err(|_| anyhow!("runtime service stopped"))?
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

impl Drop for RuntimeHandle {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Job::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use std::path::Path;
    use std::sync::Arc;

    fn dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have() -> bool {
        dir().join("manifest.txt").exists()
    }

    #[test]
    fn run_through_service_thread() {
        if !have() {
            return;
        }
        let h = RuntimeHandle::spawn(dir()).unwrap();
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[1, 16, 16, 128], &mut rng);
        let k = Tensor::randn(&[4, 4, 128, 3], &mut rng).scale(0.05);
        let out = h.run("cgan_dc2_huge2", vec![x, k]).unwrap();
        assert_eq!(out[0].shape(), &[1, 32, 32, 3]);
    }

    #[test]
    fn bound_weights_stay_resident() {
        if !have() {
            return;
        }
        let h = RuntimeHandle::spawn(dir()).unwrap();
        let mut rng = Rng::new(4);
        let k = Tensor::randn(&[4, 4, 128, 3], &mut rng).scale(0.05);
        h.bind("w", vec![k.clone()]).unwrap();
        let x = Tensor::randn(&[1, 16, 16, 128], &mut rng);
        let a = h.run_bound("cgan_dc2_huge2", vec![x.clone()], "w").unwrap();
        let b = h.run("cgan_dc2_huge2", vec![x, k]).unwrap();
        assert!(a[0].allclose(&b[0], 1e-6));
    }

    #[test]
    fn handle_shared_across_threads() {
        if !have() {
            return;
        }
        let h = Arc::new(RuntimeHandle::spawn(dir()).unwrap());
        h.warm("cgan_dc2_huge2").unwrap();
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                let x = Tensor::randn(&[1, 16, 16, 128], &mut rng);
                let k = Tensor::randn(&[4, 4, 128, 3], &mut rng);
                let out = h.run("cgan_dc2_huge2", vec![x, k]).unwrap();
                assert_eq!(out[0].shape(), &[1, 32, 32, 3]);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn unknown_bind_key_is_clean_error() {
        if !have() {
            return;
        }
        let h = RuntimeHandle::spawn(dir()).unwrap();
        let x = Tensor::zeros(&[1, 16, 16, 128]);
        assert!(h.run_bound("cgan_dc2_huge2", vec![x], "nope").is_err());
    }
}
