//! Measurement harness for the paper-reproduction benches (the vendored
//! crate set has no criterion; this is the hand-rolled equivalent:
//! warmup, N samples, median + MAD, throughput, aligned table output).

use std::time::{Duration, Instant};

/// Result of measuring one closure.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    pub median: Duration,
    /// Median absolute deviation — robust spread estimate.
    pub mad: Duration,
    pub min: Duration,
    pub max: Duration,
    pub samples: usize,
}

impl Measurement {
    pub fn median_s(&self) -> f64 {
        self.median.as_secs_f64()
    }

    /// Relative spread (MAD / median).
    pub fn rel_spread(&self) -> f64 {
        if self.median.is_zero() {
            0.0
        } else {
            self.mad.as_secs_f64() / self.median.as_secs_f64()
        }
    }
}

/// Measure `f`: `warmup` discarded runs, then `samples` timed runs.
pub fn measure(warmup: usize, samples: usize,
               mut f: impl FnMut()) -> Measurement {
    assert!(samples > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = (0..samples)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed()
        })
        .collect();
    times.sort_unstable();
    let median = times[times.len() / 2];
    let mut devs: Vec<Duration> = times
        .iter()
        .map(|&t| if t > median { t - median } else { median - t })
        .collect();
    devs.sort_unstable();
    Measurement {
        median,
        mad: devs[devs.len() / 2],
        min: times[0],
        max: *times.last().unwrap(),
        samples,
    }
}

/// Adaptive variant: keeps a time budget by shrinking samples for slow
/// closures (at least 3 samples).
pub fn measure_budget(budget: Duration, mut f: impl FnMut()) -> Measurement {
    let t0 = Instant::now();
    f(); // warmup + cost probe
    let probe = t0.elapsed();
    let n = ((budget.as_secs_f64() / probe.as_secs_f64().max(1e-9)) as usize)
        .clamp(3, 30);
    measure(0, n, f)
}

/// Human-friendly duration.
pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Simple aligned-table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!("{c:>w$}  ", w = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>()
                                  + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_runs() {
        let mut n = 0;
        let m = measure(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(m.samples, 5);
        assert!(m.min <= m.median && m.median <= m.max);
    }

    #[test]
    fn measures_sleep_roughly() {
        let m = measure(0, 3,
                        || std::thread::sleep(Duration::from_millis(5)));
        assert!(m.median >= Duration::from_millis(4));
        assert!(m.median < Duration::from_millis(50));
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_micros(7)).ends_with("µs"));
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // smoke: no panic
    }
}
