//! Minimal TOML-subset parser (no external crates available).
//!
//! Supports exactly what the engine's config files need:
//! `key = int | float | "string" | true/false | [int, int, ...]`,
//! `#` comments, blank lines. No tables, no nesting — by design; config
//! files stay flat and greppable.

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Str(String),
    Bool(bool),
    IntList(Vec<i64>),
}

/// Parse the subset; returns key/value pairs in file order.
pub fn parse_toml(text: &str) -> Result<Vec<(String, TomlValue)>, String> {
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        if key.is_empty()
            || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
        {
            return Err(format!("line {}: bad key {key:?}", lineno + 1));
        }
        out.push((key.to_string(), parse_value(val.trim(), lineno + 1)?));
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quotes is content, not a comment.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str, lineno: usize) -> Result<TomlValue, String> {
    if v.is_empty() {
        return Err(format!("line {lineno}: empty value"));
    }
    if let Some(body) = v.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| format!("line {lineno}: unterminated string"))?;
        if body.contains('"') {
            return Err(format!("line {lineno}: embedded quote"));
        }
        return Ok(TomlValue::Str(body.to_string()));
    }
    if let Some(body) = v.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| format!("line {lineno}: unterminated list"))?;
        let mut xs = Vec::new();
        for item in body.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            xs.push(item.parse::<i64>().map_err(|_| {
                format!("line {lineno}: non-integer list item {item:?}")
            })?);
        }
        return Ok(TomlValue::IntList(xs));
    }
    match v {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("line {lineno}: cannot parse value {v:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_types() {
        let m = parse_toml(
            "a = 3\nb = 2.5\nc = \"hi # there\"\nd = true\ne = [1, 2, 3]\n\
             # full comment\n\nf = -7 # trailing\n",
        )
        .unwrap();
        assert_eq!(m[0], ("a".into(), TomlValue::Int(3)));
        assert_eq!(m[1], ("b".into(), TomlValue::Float(2.5)));
        assert_eq!(m[2], ("c".into(), TomlValue::Str("hi # there".into())));
        assert_eq!(m[3], ("d".into(), TomlValue::Bool(true)));
        assert_eq!(m[4], ("e".into(), TomlValue::IntList(vec![1, 2, 3])));
        assert_eq!(m[5], ("f".into(), TomlValue::Int(-7)));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_toml("novalue").is_err());
        assert!(parse_toml("k = \"open").is_err());
        assert!(parse_toml("k = [1, 2").is_err());
        assert!(parse_toml("bad key = 1").is_err());
        assert!(parse_toml("k = what").is_err());
    }

    #[test]
    fn empty_ok() {
        assert!(parse_toml("").unwrap().is_empty());
        assert!(parse_toml("\n# only comments\n").unwrap().is_empty());
    }
}
