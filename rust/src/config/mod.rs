//! Configuration system: the paper's Table-1 workload plus the serving
//! engine's runtime configuration, loadable from a minimal TOML subset
//! (the vendored crate set has no serde/toml — the parser is local).

mod toml_mini;

pub use toml_mini::{parse_toml, TomlValue};

use crate::deconv::{DeconvParams, DilatedParams, Engine};

/// One Table-1 row: a stride-2 transposed-convolution layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerConfig {
    pub name: &'static str,
    pub gan: &'static str,
    /// Input spatial size (square).
    pub h: usize,
    pub c_in: usize,
    pub c_out: usize,
    /// Kernel size (square).
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub out_pad: usize,
}

impl LayerConfig {
    pub fn deconv_params(&self) -> DeconvParams {
        DeconvParams::new(self.stride, self.pad, self.out_pad)
    }

    pub fn h_out(&self) -> usize {
        self.deconv_params().out_size(self.h, self.k)
    }

    /// Input/kernel/output element counts (batch 1).
    pub fn sizes(&self) -> (usize, usize, usize) {
        let ho = self.h_out();
        (
            self.h * self.h * self.c_in,
            self.k * self.k * self.c_in * self.c_out,
            ho * ho * self.c_out,
        )
    }
}

/// The paper's Table 1: DCGAN DC1–DC4 and cGAN DC1–DC2 (CIFAR geometry).
pub fn table1() -> Vec<LayerConfig> {
    vec![
        LayerConfig { name: "dcgan_dc1", gan: "DCGAN", h: 4, c_in: 1024,
                      c_out: 512, k: 5, stride: 2, pad: 2, out_pad: 1 },
        LayerConfig { name: "dcgan_dc2", gan: "DCGAN", h: 8, c_in: 512,
                      c_out: 256, k: 5, stride: 2, pad: 2, out_pad: 1 },
        LayerConfig { name: "dcgan_dc3", gan: "DCGAN", h: 16, c_in: 256,
                      c_out: 128, k: 5, stride: 2, pad: 2, out_pad: 1 },
        LayerConfig { name: "dcgan_dc4", gan: "DCGAN", h: 32, c_in: 128,
                      c_out: 3, k: 5, stride: 2, pad: 2, out_pad: 1 },
        LayerConfig { name: "cgan_dc1", gan: "cGAN", h: 8, c_in: 256,
                      c_out: 128, k: 4, stride: 2, pad: 1, out_pad: 0 },
        LayerConfig { name: "cgan_dc2", gan: "cGAN", h: 16, c_in: 128,
                      c_out: 3, k: 4, stride: 2, pad: 1, out_pad: 0 },
    ]
}

pub fn dcgan_layers() -> Vec<LayerConfig> {
    table1().into_iter().filter(|l| l.gan == "DCGAN").collect()
}

pub fn cgan_layers() -> Vec<LayerConfig> {
    table1().into_iter().filter(|l| l.gan == "cGAN").collect()
}

pub fn layer_by_name(name: &str) -> Option<LayerConfig> {
    table1().into_iter().find(|l| l.name == name)
}

/// Dilated-conv workloads for the Fig.-8 training / segmentation benches.
pub fn dilated_workloads() -> Vec<(&'static str, usize, usize, usize, usize,
                                   DilatedParams)> {
    // (name, h, c, n, r, params)
    vec![
        ("seg_aspp_d2", 33, 64, 64, 3, DilatedParams::new(2, 1, 2)),
        ("seg_aspp_d4", 33, 64, 64, 3, DilatedParams::new(4, 1, 4)),
        ("seg_aspp_d8", 33, 64, 64, 3, DilatedParams::new(8, 1, 8)),
        ("disc_bwd_16", 16, 32, 32, 3, DilatedParams::new(2, 1, 2)),
    ]
}

/// One segmentation-net layer: a dilated (atrous) convolution, with a
/// per-layer choice of engine and threading — the seg analogue of
/// [`LayerConfig`]. Geometry follows [`DilatedParams::out_size`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SegLayerConfig {
    pub name: &'static str,
    /// Input spatial size (square).
    pub h: usize,
    pub c_in: usize,
    pub c_out: usize,
    /// Kernel size (square).
    pub k: usize,
    pub params: DilatedParams,
    /// Baseline vs HUGE² untangled dilated conv for this layer — or
    /// [`Engine::Auto`] to resolve from the plan heuristic at load time.
    pub engine: Engine,
    /// Threads for this layer's forward (1 = single-threaded). The MT
    /// engine is bit-identical across thread counts, so this is a pure
    /// throughput knob — it never perturbs replay checksums.
    pub threads: usize,
}

impl SegLayerConfig {
    pub fn h_out(&self) -> usize {
        self.params.out_size(self.h, self.k)
    }
}

/// A segmentation network: sequential trunk → parallel atrous pyramid
/// (branches summed) → 1×1 classifier head (DeepLab/ENet shape, §2.1.2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SegNetConfig {
    /// Registry name ([`segnet_by_name`]); recorded in trace headers so
    /// `huge2 replay` can rebuild the exact net from the file alone.
    pub name: &'static str,
    pub trunk: Vec<SegLayerConfig>,
    pub aspp: Vec<SegLayerConfig>,
    pub head: SegLayerConfig,
    pub n_classes: usize,
}

/// Registry default: resolve each layer's engine (and, for heavy
/// layers, its thread count) at plan-compile time from the build-time
/// heuristic in [`crate::plan`] — "load-time engine selection"
/// (DESIGN.md §10). Explicit `Engine::Baseline`/`Engine::Huge2` remain
/// valid per-layer choices.
const SEG_AUTO: Engine = Engine::Auto;

/// The canonical serving segnet: 33×33×3 input, ASPP at dilations
/// 1/2/4/8 over 64 channels (the same geometry as [`dilated_workloads`]),
/// 12-class head. Early (large) layers run the multi-threaded dilated
/// engine.
pub fn segnet() -> SegNetConfig {
    let d = |dil: usize| DilatedParams::new(dil, 1, dil); // 'same' padding
    SegNetConfig {
        name: "segnet",
        trunk: vec![
            SegLayerConfig { name: "seg_enc1", h: 33, c_in: 3, c_out: 32,
                             k: 3, params: d(1), engine: SEG_AUTO,
                             threads: 4 },
            SegLayerConfig { name: "seg_enc2", h: 33, c_in: 32, c_out: 64,
                             k: 3, params: d(2), engine: SEG_AUTO,
                             threads: 4 },
        ],
        aspp: vec![
            SegLayerConfig { name: "seg_aspp_d1", h: 33, c_in: 64,
                             c_out: 64, k: 3, params: d(1),
                             engine: SEG_AUTO, threads: 1 },
            SegLayerConfig { name: "seg_aspp_d2", h: 33, c_in: 64,
                             c_out: 64, k: 3, params: d(2),
                             engine: SEG_AUTO, threads: 1 },
            SegLayerConfig { name: "seg_aspp_d4", h: 33, c_in: 64,
                             c_out: 64, k: 3, params: d(4),
                             engine: SEG_AUTO, threads: 1 },
            SegLayerConfig { name: "seg_aspp_d8", h: 33, c_in: 64,
                             c_out: 64, k: 3, params: d(8),
                             engine: SEG_AUTO, threads: 1 },
        ],
        head: SegLayerConfig { name: "seg_head", h: 33, c_in: 64,
                               c_out: 12, k: 1,
                               params: DilatedParams::new(1, 1, 0),
                               engine: SEG_AUTO, threads: 1 },
        n_classes: 12,
    }
}

/// Shrunk segnet (9×9×2 input, 3 classes) — the fast, bit-reproducible
/// model for tests and benches, the seg analogue of
/// [`crate::gan::Generator::tiny_cgan`].
pub fn tiny_segnet() -> SegNetConfig {
    let d = |dil: usize| DilatedParams::new(dil, 1, dil);
    SegNetConfig {
        name: "tiny_segnet",
        trunk: vec![SegLayerConfig { name: "tseg_enc1", h: 9, c_in: 2,
                                     c_out: 4, k: 3, params: d(1),
                                     engine: SEG_AUTO, threads: 1 }],
        aspp: vec![
            SegLayerConfig { name: "tseg_aspp_d1", h: 9, c_in: 4, c_out: 4,
                             k: 3, params: d(1), engine: SEG_AUTO,
                             threads: 1 },
            SegLayerConfig { name: "tseg_aspp_d2", h: 9, c_in: 4, c_out: 4,
                             k: 3, params: d(2), engine: SEG_AUTO,
                             threads: 1 },
        ],
        head: SegLayerConfig { name: "tseg_head", h: 9, c_in: 4, c_out: 3,
                               k: 1, params: DilatedParams::new(1, 1, 0),
                               engine: SEG_AUTO, threads: 1 },
        n_classes: 3,
    }
}

/// Seg-net registry: the names trace headers / the CLI accept.
pub fn segnet_by_name(name: &str) -> Option<SegNetConfig> {
    match name {
        "segnet" => Some(segnet()),
        "tiny_segnet" => Some(tiny_segnet()),
        _ => None,
    }
}

/// Serving-engine runtime configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    /// Max requests fused into one batch.
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch (µs).
    pub batch_timeout_us: u64,
    /// Bounded-queue depth before backpressure rejects.
    pub queue_depth: usize,
    /// Worker threads executing compiled artifacts.
    pub workers: usize,
    /// Directory of AOT artifacts.
    pub artifact_dir: String,
    /// Batch-size buckets compiled ahead of time (must match aot.py).
    pub batch_buckets: Vec<usize>,
    /// Arm the observability layer (stage-span histograms + flight
    /// recorder, DESIGN.md §12). On by default — the hot-path cost is a
    /// few `Instant` reads and lock-free counter increments per request
    /// (the serving bench's instrumentation-overhead phase pins it).
    pub instrument: bool,
    /// Flight-recorder ring capacity (recent span events retained for
    /// the panic-path dump).
    pub flight_capacity: usize,
    /// Continuous batching (DESIGN.md §16): workers admit newly queued
    /// rows into the *next* forming batch while the current one
    /// executes, seating by (priority, arrival) and carrying spill
    /// forward with its original arrival anchor. `false` falls back to
    /// the windowed batcher (one batch window at a time).
    pub continuous: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 8,
            batch_timeout_us: 2000,
            queue_depth: 256,
            workers: 2,
            artifact_dir: "artifacts".to_string(),
            batch_buckets: vec![1, 4, 8],
            instrument: true,
            flight_capacity: 1024,
            continuous: true,
        }
    }
}

impl EngineConfig {
    /// Load from the minimal-TOML config format:
    ///
    /// ```toml
    /// max_batch = 8
    /// batch_timeout_us = 2000
    /// queue_depth = 256
    /// workers = 2
    /// artifact_dir = "artifacts"
    /// batch_buckets = [1, 4, 8]
    /// instrument = true
    /// flight_capacity = 1024
    /// continuous = true
    /// ```
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let map = parse_toml(text)?;
        let mut cfg = EngineConfig::default();
        for (k, v) in &map {
            match (k.as_str(), v) {
                ("max_batch", TomlValue::Int(i)) => cfg.max_batch = *i as usize,
                ("batch_timeout_us", TomlValue::Int(i)) => {
                    cfg.batch_timeout_us = *i as u64
                }
                ("queue_depth", TomlValue::Int(i)) => {
                    cfg.queue_depth = *i as usize
                }
                ("workers", TomlValue::Int(i)) => cfg.workers = *i as usize,
                ("artifact_dir", TomlValue::Str(s)) => {
                    cfg.artifact_dir = s.clone()
                }
                ("batch_buckets", TomlValue::IntList(xs)) => {
                    cfg.batch_buckets =
                        xs.iter().map(|&x| x as usize).collect()
                }
                ("instrument", TomlValue::Bool(b)) => cfg.instrument = *b,
                ("flight_capacity", TomlValue::Int(i)) => {
                    cfg.flight_capacity = *i as usize
                }
                ("continuous", TomlValue::Bool(b)) => cfg.continuous = *b,
                (other, _) => {
                    return Err(format!("unknown or mistyped key: {other}"))
                }
            }
        }
        if cfg.max_batch == 0 || cfg.workers == 0 || cfg.queue_depth == 0 {
            return Err("max_batch, workers, queue_depth must be > 0".into());
        }
        if cfg.batch_buckets.is_empty() {
            return Err("batch_buckets must be non-empty".into());
        }
        cfg.batch_buckets.sort_unstable();
        Ok(cfg)
    }

    /// Smallest compiled bucket that fits `n` requests (else the largest).
    pub fn bucket_for(&self, n: usize) -> usize {
        *self
            .batch_buckets
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or(self.batch_buckets.last().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let t = table1();
        assert_eq!(t.len(), 6);
        assert_eq!(t[0].c_in, 1024);
        assert_eq!(t[0].h_out(), 8);
        assert_eq!(t[3].h_out(), 64);
        assert_eq!(t[4].k, 4);
        assert_eq!(t[4].h_out(), 16);
        // layers chain
        for w in dcgan_layers().windows(2) {
            assert_eq!(w[0].h_out(), w[1].h);
            assert_eq!(w[0].c_out, w[1].c_in);
        }
    }

    #[test]
    fn segnet_configs_chain() {
        for cfg in [segnet(), tiny_segnet()] {
            // trunk chains spatially and channel-wise
            for w in cfg.trunk.windows(2) {
                assert_eq!(w[0].h_out(), w[1].h, "{}", cfg.name);
                assert_eq!(w[0].c_out, w[1].c_in, "{}", cfg.name);
            }
            let last = cfg.trunk.last().unwrap();
            // every ASPP branch consumes the trunk output and produces
            // the same shape (branches are summed)
            for b in &cfg.aspp {
                assert_eq!(b.h, last.h_out(), "{}:{}", cfg.name, b.name);
                assert_eq!(b.c_in, last.c_out, "{}:{}", cfg.name, b.name);
                assert_eq!(b.h_out(), cfg.aspp[0].h_out());
                assert_eq!(b.c_out, cfg.aspp[0].c_out);
            }
            assert_eq!(cfg.head.c_in, cfg.aspp[0].c_out);
            assert_eq!(cfg.head.h, cfg.aspp[0].h_out());
            assert_eq!(cfg.head.c_out, cfg.n_classes);
            assert_eq!(segnet_by_name(cfg.name), Some(cfg));
        }
        assert!(segnet_by_name("nope").is_none());
    }

    #[test]
    fn engine_config_from_toml() {
        let cfg = EngineConfig::from_toml(
            "max_batch = 16\nworkers = 4\nartifact_dir = \"a/b\"\n\
             batch_buckets = [1, 2, 16]\n# comment\n",
        )
        .unwrap();
        assert_eq!(cfg.max_batch, 16);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.artifact_dir, "a/b");
        assert_eq!(cfg.batch_buckets, vec![1, 2, 16]);
        // untouched field keeps default
        assert_eq!(cfg.queue_depth, 256);
    }

    #[test]
    fn engine_config_rejects_bad_keys() {
        assert!(EngineConfig::from_toml("nope = 3").is_err());
        assert!(EngineConfig::from_toml("workers = 0").is_err());
    }

    #[test]
    fn bucket_selection() {
        let cfg = EngineConfig::default();
        assert_eq!(cfg.bucket_for(1), 1);
        assert_eq!(cfg.bucket_for(2), 4);
        assert_eq!(cfg.bucket_for(5), 8);
        assert_eq!(cfg.bucket_for(99), 8);
    }
}
