"""L2 model tests: generator shapes, engine equivalence, train step."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def close(a, b, tol=5e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=tol, rtol=tol)


class TestTable1:
    def test_dcgan_geometry(self):
        """Table 1 (paper): 4x4x1024 -> 8 -> 16 -> 32x32x3."""
        hs = [l.h for l in model.DCGAN_LAYERS]
        assert hs == [4, 8, 16, 32]
        outs = [l.h_out for l in model.DCGAN_LAYERS]
        assert outs == [8, 16, 32, 64][:0] or outs == [8, 16, 32, 64]
        cs = [(l.c_in, l.c_out) for l in model.DCGAN_LAYERS]
        assert cs == [(1024, 512), (512, 256), (256, 128), (128, 3)]

    def test_cgan_geometry(self):
        assert [(l.h, l.c_in, l.c_out, l.k) for l in model.CGAN_LAYERS] == \
            [(8, 256, 128, 4), (16, 128, 3, 4)]
        assert [l.h_out for l in model.CGAN_LAYERS] == [16, 32]

    def test_layers_chain(self):
        for a, b in zip(model.DCGAN_LAYERS, model.DCGAN_LAYERS[1:]):
            assert a.h_out == b.h and a.c_out == b.c_in


class TestGenerators:
    def _tiny_params(self, layers, z_dim):
        # shrink channels so interpret-mode forward is fast
        small = [model.DeconvLayer(l.name, l.h, max(1, l.c_in // 16),
                                   l.c_out if l.c_out <= 3
                                   else max(1, l.c_out // 16),
                                   l.k, l.stride, l.pad, l.out_pad)
                 for l in layers]
        # re-chain channels
        fixed = []
        for i, l in enumerate(small):
            c_in = fixed[-1].c_out if i else l.c_in
            fixed.append(model.DeconvLayer(l.name, l.h, c_in, l.c_out,
                                           l.k, l.stride, l.pad, l.out_pad))
        return fixed

    def test_dcgan_engines_agree(self):
        layers = self._tiny_params(model.DCGAN_LAYERS, model.Z_DIM)
        params = model.init_dcgan_generator(jax.random.PRNGKey(0),
                                            layers=layers)
        z = jax.random.normal(jax.random.PRNGKey(1), (2, model.Z_DIM))
        a = model.dcgan_generator(params, z, engine="huge2", layers=layers)
        b = model.dcgan_generator(params, z, engine="baseline",
                                  layers=layers)
        c = model.dcgan_generator(params, z, engine="oracle", layers=layers)
        assert a.shape == (2, 64, 64, 3)
        close(a, b)
        close(a, c)
        # tanh output range
        assert np.abs(np.asarray(a)).max() <= 1.0

    def test_cgan_engines_agree(self):
        layers = self._tiny_params(model.CGAN_LAYERS, model.Z_DIM)
        params = model.init_cgan_generator(jax.random.PRNGKey(0),
                                           layers=layers)
        z = jax.random.normal(jax.random.PRNGKey(1), (1, model.Z_DIM))
        y = jax.nn.one_hot(jnp.array([3]), model.N_CLASSES)
        a = model.cgan_generator(params, z, y, engine="huge2", layers=layers)
        b = model.cgan_generator(params, z, y, engine="baseline",
                                 layers=layers)
        assert a.shape == (1, 32, 32, 3)
        close(a, b)

    def test_discriminator_shape(self):
        params = model.init_discriminator(jax.random.PRNGKey(0))
        img = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 3))
        assert model.discriminator(params, img).shape == (4, 1)

    def test_atrous_pyramid_engines_agree(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 17, 17, 4))
        ks = [jax.random.normal(jax.random.PRNGKey(i + 1), (3, 3, 4, 4))
              * 0.1 for i in range(4)]
        a = model.atrous_pyramid(x, ks, engine="huge2")
        b = model.atrous_pyramid(x, ks, engine="baseline")
        assert a.shape == x.shape[:3] + (4,)
        close(a, b)


class TestTraining:
    def test_train_step_decreases_d_loss(self):
        gen, disc = model.init_tiny_gan(jax.random.PRNGKey(0))
        z = jax.random.normal(jax.random.PRNGKey(1), (8, model.TINY_Z))
        real = jnp.tanh(
            jax.random.normal(jax.random.PRNGKey(2), (8, 32, 32, 3)))
        step = jax.jit(model.gan_train_step)
        g, d, lg0, ld0 = step(gen, disc, z, real)
        for _ in range(5):
            g, d, lg, ld = step(g, d, z, real)
        assert np.isfinite(float(lg)) and np.isfinite(float(ld))
        assert float(ld) < float(ld0)  # D learns on a fixed batch

    def test_param_shapes_stable(self):
        gen, disc = model.init_tiny_gan(jax.random.PRNGKey(0))
        z = jax.random.normal(jax.random.PRNGKey(1), (4, model.TINY_Z))
        real = jnp.zeros((4, 32, 32, 3))
        g, d, _, _ = model.gan_train_step(gen, disc, z, real)
        for k in gen:
            assert g[k].shape == gen[k].shape
        for k in disc:
            assert d[k].shape == disc[k].shape
