"""Core correctness signal: HUGE2 Pallas kernels vs pure-jnp oracles.

Every algorithmic identity of the paper is checked:
  * decomposition + untangling == zero-insertion transposed conv (Alg 1)
  * untangled dilated conv     == zero-dilated-kernel conv (Alg 2)
  * weight-grad-as-dilated-conv == jax.grad of the forward conv (3.2.3)
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import ref, untangled, decomposed, dilated
from compile import model

RNG = np.random.default_rng(1234)


def randn(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


def assert_close(a, b, atol=2e-4, rtol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=atol, rtol=rtol)


# --------------------------------------------------------------------------
# Pallas GEMM primitive
# --------------------------------------------------------------------------

class TestMatmul:
    @pytest.mark.parametrize("m,k,n", [
        (1, 1, 1), (3, 5, 7), (16, 16, 16), (128, 64, 32),
        (130, 70, 33),  # non-divisible by any tile
        (256, 128, 256),
    ])
    def test_matches_jnp(self, m, k, n):
        x, w = randn(m, k), randn(k, n)
        assert_close(untangled.matmul(x, w), x @ w)

    @pytest.mark.parametrize("m,k,n", [(5, 3, 4), (64, 128, 64), (33, 17, 9)])
    def test_acc_matches_jnp(self, m, k, n):
        x, w, a = randn(m, k), randn(k, n), randn(m, n)
        assert_close(untangled.matmul_acc(x, w, a), a + x @ w)

    def test_small_tiles(self):
        x, w = randn(40, 24), randn(24, 40)
        assert_close(untangled.matmul(x, w, tm=16, tn=16, tk=8), x @ w)

    def test_vmem_budget(self):
        # default tile fits comfortably in one TPU core's VMEM (~16 MiB)
        assert untangled.vmem_bytes() < 16 * 2 ** 20 // 4


# --------------------------------------------------------------------------
# Oracles agree with each other (lax lhs-dilation vs literal zero-insertion)
# --------------------------------------------------------------------------

class TestOracles:
    @pytest.mark.parametrize("h,c,n,r,stride,pad,op", [
        (4, 8, 6, 5, 2, 2, 1),
        (8, 4, 4, 4, 2, 1, 0),
        (5, 3, 2, 3, 2, 1, 1),
        (6, 2, 3, 3, 3, 0, 0),
        (7, 1, 1, 5, 2, 2, 1),
    ])
    def test_transpose_oracles_agree(self, h, c, n, r, stride, pad, op):
        x, k = randn(1, h, h, c), randn(r, r, c, n)
        a = ref.conv2d_transpose(x, k, stride, pad, op)
        b = ref.conv2d_transpose_zerofill(x, k, stride, pad, op)
        assert a.shape[1] == ref.out_size_transpose(h, stride, r, pad, op)
        assert_close(a, b)

    @pytest.mark.parametrize("d,st,pad", [(2, 1, 0), (2, 1, 2), (3, 1, 3),
                                          (2, 2, 2), (4, 1, 4)])
    def test_dilated_oracles_agree(self, d, st, pad):
        x, k = randn(1, 13, 13, 5), randn(3, 3, 5, 4)
        a = ref.conv2d_dilated(x, k, d, st, pad)
        b = ref.conv2d_dilated_zerofill(x, k, d, st, pad)
        assert_close(a, b)

    def test_weight_grad_matches_autodiff(self):
        x, k = randn(2, 8, 8, 5), randn(5, 5, 5, 7)
        y = ref.conv2d(x, k, stride=2, pad=2)
        dy = randn(*y.shape)
        g_ref = ref.weight_grad_dilated(x, dy, stride=2, pad=2, r=5, s=5)
        g_ad = jax.grad(
            lambda kk: jnp.sum(ref.conv2d(x, kk, stride=2, pad=2) * dy))(k)
        assert_close(g_ref, g_ad)

    def test_input_grad_matches_autodiff(self):
        x, k = randn(1, 8, 8, 4), randn(5, 5, 4, 6)
        y = ref.conv2d(x, k, stride=2, pad=2)
        dy = randn(*y.shape)
        g_ad = jax.grad(
            lambda xx: jnp.sum(ref.conv2d(xx, k, stride=2, pad=2) * dy))(x)
        g_ref = ref.input_grad_transpose(dy, k, stride=2, pad=2, out_pad=1)
        assert_close(g_ad, g_ref)


# --------------------------------------------------------------------------
# HUGE2 decomposed transposed conv (the headline kernel)
# --------------------------------------------------------------------------

class TestDecomposed:
    @pytest.mark.parametrize("layer", model.ALL_LAYERS,
                             ids=[l.name for l in model.ALL_LAYERS])
    def test_table1_layers(self, layer):
        """Every Table-1 configuration, exact vs oracle."""
        # shrink channels 8x to keep interpret-mode runtime sane; spatial
        # geometry (the decomposition) is exercised at full fidelity
        c = max(1, layer.c_in // 8)
        n = max(1, layer.c_out // 8) if layer.c_out > 3 else layer.c_out
        x = randn(1, layer.h, layer.h, c)
        k = randn(layer.k, layer.k, c, n)
        got = decomposed.conv2d_transpose_huge2(
            x, k, layer.stride, layer.pad, layer.out_pad)
        want = ref.conv2d_transpose(x, k, layer.stride, layer.pad,
                                    layer.out_pad)
        assert got.shape == (1, layer.h_out, layer.h_out, n)
        assert_close(got, want)

    @pytest.mark.parametrize("stride", [2, 3, 4])
    def test_higher_strides(self, stride):
        x, k = randn(1, 5, 5, 3), randn(2 * stride + 1, 2 * stride + 1, 3, 2)
        got = decomposed.conv2d_transpose_huge2(x, k, stride, stride, 1)
        want = ref.conv2d_transpose(x, k, stride, stride, 1)
        assert_close(got, want)

    def test_batch(self):
        x, k = randn(3, 4, 4, 4), randn(5, 5, 4, 2)
        assert_close(decomposed.conv2d_transpose_huge2(x, k),
                     ref.conv2d_transpose(x, k))

    def test_rect_kernel(self):
        x, k = randn(1, 6, 6, 2), randn(3, 3, 2, 2)
        got = decomposed.conv2d_transpose_huge2(x, k, 2, 1, 0)
        want = ref.conv2d_transpose(x, k, 2, 1, 0)
        assert_close(got, want)

    def test_pattern_count(self):
        pats = decomposed.decompose_kernel(randn(5, 5, 2, 2), 2, 2)
        assert len(pats) == 4  # the paper's 4 patterns for stride 2
        # Taps partition the 5x5 kernel: sum of tap counts == 25
        total = sum(v[0].shape[0] * v[0].shape[1] for v in pats.values())
        assert total == 25

    def test_flop_count_dcgan_dc1(self):
        fc = decomposed.flop_count(4, 4, 1024, 512, 5, 5, 2, 2, 1)
        # naive slides a 5x5 window over the inflated tensor: 8*8*25*C*N
        assert fc["naive_macs"] == 8 * 8 * 25 * 1024 * 512
        # stride-2 decomposition removes ~3/4 of the MACs
        assert 3.0 < fc["ratio"] < 4.5


# --------------------------------------------------------------------------
# HUGE2 dilated conv + training gradients
# --------------------------------------------------------------------------

class TestDilated:
    @pytest.mark.parametrize("d,st,pad", [(2, 1, 2), (3, 1, 3), (2, 2, 2),
                                          (4, 1, 4), (2, 1, 0)])
    def test_matches_oracle(self, d, st, pad):
        x, k = randn(1, 13, 13, 6), randn(3, 3, 6, 5)
        got = dilated.conv2d_dilated_huge2(x, k, d, st, pad)
        want = ref.conv2d_dilated(x, k, d, st, pad)
        assert_close(got, want)

    def test_batch(self):
        x, k = randn(2, 9, 9, 3), randn(3, 3, 3, 3)
        assert_close(dilated.conv2d_dilated_huge2(x, k, 2, 1, 2),
                     ref.conv2d_dilated(x, k, 2, 1, 2))

    def test_weight_grad_matches_oracle_and_autodiff(self):
        x, k = randn(2, 8, 8, 4), randn(5, 5, 4, 6)
        y = ref.conv2d(x, k, stride=2, pad=2)
        dy = randn(*y.shape)
        got = dilated.weight_grad_huge2(x, dy, stride=2, pad=2, r=5, s=5)
        want = ref.weight_grad_dilated(x, dy, stride=2, pad=2, r=5, s=5)
        g_ad = jax.grad(
            lambda kk: jnp.sum(ref.conv2d(x, kk, stride=2, pad=2) * dy))(k)
        assert_close(got, want)
        assert_close(got, g_ad)

    def test_depthwise_outer_product_case(self):
        # paper 3.2.3: C=1 dilated conv == outer product of two vectors
        x, k = randn(1, 7, 7, 1), randn(3, 3, 1, 1)
        assert_close(dilated.conv2d_dilated_huge2(x, k, 2, 1, 0),
                     ref.conv2d_dilated(x, k, 2, 1, 0))
