"""Hypothesis sweeps over the Pallas kernels' shape/parameter space.

These are the python-side property tests the deliverables require: random
shapes, strides, pads and dtypes, always asserting allclose against the
pure-jnp oracle in ref.py.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, untangled, decomposed, dilated

SETTINGS = dict(max_examples=25, deadline=None)


def arr(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


def close(a, b, tol=3e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=tol, rtol=tol)


@settings(**SETTINGS)
@given(m=st.integers(1, 200), k=st.integers(1, 96), n=st.integers(1, 96),
       seed=st.integers(0, 2 ** 31))
def test_matmul_any_shape(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = arr(rng, m, k), arr(rng, k, n)
    close(untangled.matmul(x, w), x @ w)


@settings(**SETTINGS)
@given(m=st.integers(1, 100), k=st.integers(1, 64), n=st.integers(1, 64),
       seed=st.integers(0, 2 ** 31))
def test_matmul_acc_any_shape(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w, a = arr(rng, m, k), arr(rng, k, n), arr(rng, m, n)
    close(untangled.matmul_acc(x, w, a), a + x @ w)


@settings(**SETTINGS)
@given(h=st.integers(2, 9), c=st.integers(1, 8), n=st.integers(1, 8),
       r=st.integers(2, 5), stride=st.integers(2, 3),
       out_pad=st.integers(0, 1), pad_frac=st.integers(0, 100),
       seed=st.integers(0, 2 ** 31))
def test_transpose_decomposition_any_config(h, c, n, r, stride, out_pad,
                                            pad_frac, seed):
    """The §3.1 decomposition identity holds for *any* legal configuration,
    not just the paper's Table-1 rows."""
    pad = pad_frac % r  # any pad in [0, r)
    out_pad = min(out_pad, stride - 1)
    if ref.out_size_transpose(h, stride, r, pad, out_pad) <= 0:
        return
    rng = np.random.default_rng(seed)
    x, k = arr(rng, 1, h, h, c), arr(rng, r, r, c, n)
    close(decomposed.conv2d_transpose_huge2(x, k, stride, pad, out_pad),
          ref.conv2d_transpose(x, k, stride, pad, out_pad))


@settings(**SETTINGS)
@given(h=st.integers(5, 16), c=st.integers(1, 6), n=st.integers(1, 6),
       r=st.integers(1, 3), d=st.integers(1, 4), stride=st.integers(1, 2),
       pad=st.integers(0, 4), seed=st.integers(0, 2 ** 31))
def test_dilated_untangling_any_config(h, c, n, r, d, stride, pad, seed):
    if ref.out_size_dilated(h, r, d, stride, pad) <= 0:
        return
    rng = np.random.default_rng(seed)
    x, k = arr(rng, 1, h, h, c), arr(rng, r, r, c, n)
    close(dilated.conv2d_dilated_huge2(x, k, d, stride, pad),
          ref.conv2d_dilated(x, k, d, stride, pad))


@settings(**SETTINGS)
@given(b=st.integers(1, 3), h=st.sampled_from([8, 12, 16]),
       c=st.integers(1, 5), n=st.integers(1, 5),
       seed=st.integers(0, 2 ** 31))
def test_weight_grad_any_config(b, h, c, n, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, b, h, h, c)
    k = arr(rng, 5, 5, c, n)
    y = ref.conv2d(x, k, stride=2, pad=2)
    dy = arr(rng, *y.shape)
    close(dilated.weight_grad_huge2(x, dy, stride=2, pad=2, r=5, s=5),
          ref.weight_grad_dilated(x, dy, stride=2, pad=2, r=5, s=5))


@settings(**SETTINGS)
@given(seed=st.integers(0, 2 ** 31))
def test_mac_counts_decrease(seed):
    """Invariant: the decomposition never *increases* effective MACs, and
    for stride s it removes ~(1 - 1/s^2) of them on large outputs."""
    rng = np.random.default_rng(seed)
    h = int(rng.integers(4, 32))
    r = int(rng.integers(3, 6))
    stride = int(rng.integers(2, 4))
    pad = int(rng.integers(0, r))
    fc = decomposed.flop_count(h, h, 16, 16, r, r, stride, pad,
                               min(1, stride - 1))
    assert fc["huge2_macs"] <= fc["naive_macs"]
    assert fc["ratio"] >= 1.0
