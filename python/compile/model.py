"""L2: the paper's evaluation models in JAX, calling the L1 HUGE2 kernels.

Table 1 of the paper defines the workload: the deconvolution stacks of
DCGAN (Radford et al. 2015) and cGAN (Mirza & Osindero 2014), pretrained on
CIFAR-100 (32x32 RGB).  We rebuild both generators (plus the DCGAN
discriminator needed for the training experiments) so that

* every deconv layer exists in two numerically identical variants —
  ``engine="huge2"`` (decomposed + untangled Pallas kernels) and
  ``engine="baseline"`` (the naive zero-insertion algorithm DarkNet uses);
* the full forwards lower to single HLO modules for the rust runtime;
* a complete GAN training step (both losses, SGD) lowers to one HLO module
  for the end-to-end training example.

Weights are synthetic (seeded PRNG): inference *speed* of a deconv layer is
weight-independent, and numerics are validated against the oracle instead
of CIFAR-100 sample quality (see DESIGN.md substitution table).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.decomposed import conv2d_transpose_huge2
from .kernels.dilated import conv2d_dilated_huge2


# --------------------------------------------------------------------------
# Table 1 — the paper's layer configurations.
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class DeconvLayer:
    """One Table-1 row: a stride-2 transposed-convolution layer."""
    name: str
    h: int          # input spatial size (square)
    c_in: int
    c_out: int
    k: int          # kernel size (square)
    stride: int
    pad: int
    out_pad: int

    @property
    def h_out(self) -> int:
        return ref.out_size_transpose(self.h, self.stride, self.k,
                                      self.pad, self.out_pad)


# DCGAN: 4x4x1024 -> 8 -> 16 -> 32 (CIFAR), 5x5 kernels, stride 2.
DCGAN_LAYERS: List[DeconvLayer] = [
    DeconvLayer("dcgan_dc1", 4, 1024, 512, 5, 2, 2, 1),
    DeconvLayer("dcgan_dc2", 8, 512, 256, 5, 2, 2, 1),
    DeconvLayer("dcgan_dc3", 16, 256, 128, 5, 2, 2, 1),
    DeconvLayer("dcgan_dc4", 32, 128, 3, 5, 2, 2, 1),
]

# cGAN: 8x8x256 -> 16 -> 32, 4x4 kernels, stride 2 (pad 1, no out-pad).
CGAN_LAYERS: List[DeconvLayer] = [
    DeconvLayer("cgan_dc1", 8, 256, 128, 4, 2, 1, 0),
    DeconvLayer("cgan_dc2", 16, 128, 3, 4, 2, 1, 0),
]

ALL_LAYERS: List[DeconvLayer] = DCGAN_LAYERS + CGAN_LAYERS

Z_DIM = 100
N_CLASSES = 10  # cGAN conditioning


def deconv(x, k, layer: DeconvLayer, engine: str = "huge2"):
    """Dispatch one Table-1 layer to the selected engine."""
    if engine == "huge2":
        return conv2d_transpose_huge2(x, k, stride=layer.stride,
                                      pad=layer.pad, out_pad=layer.out_pad)
    if engine == "baseline":
        return ref.conv2d_transpose_zerofill(x, k, stride=layer.stride,
                                             pad=layer.pad,
                                             out_pad=layer.out_pad)
    if engine == "oracle":
        return ref.conv2d_transpose(x, k, stride=layer.stride,
                                    pad=layer.pad, out_pad=layer.out_pad)
    raise ValueError(f"unknown engine {engine!r}")


# --------------------------------------------------------------------------
# Parameter initialisation (seeded, reproducible across python/rust).
# --------------------------------------------------------------------------

def init_dcgan_generator(key, layers=None, z_dim: int = Z_DIM) -> Dict:
    layers = layers or DCGAN_LAYERS
    first = layers[0]
    keys = jax.random.split(key, len(layers) + 1)
    params = {
        "proj_w": jax.random.normal(
            keys[0], (z_dim, first.h * first.h * first.c_in),
            jnp.float32) * 0.02,
    }
    for i, (lk, layer) in enumerate(zip(keys[1:], layers)):
        params[f"k{i}"] = jax.random.normal(
            lk, (layer.k, layer.k, layer.c_in, layer.c_out),
            jnp.float32) * 0.02
    return params


def init_cgan_generator(key, layers=None, z_dim: int = Z_DIM,
                        n_classes: int = N_CLASSES) -> Dict:
    layers = layers or CGAN_LAYERS
    first = layers[0]
    keys = jax.random.split(key, len(layers) + 1)
    params = {
        "proj_w": jax.random.normal(
            keys[0], (z_dim + n_classes, first.h * first.h * first.c_in),
            jnp.float32) * 0.02,
    }
    for i, (lk, layer) in enumerate(zip(keys[1:], layers)):
        params[f"k{i}"] = jax.random.normal(
            lk, (layer.k, layer.k, layer.c_in, layer.c_out),
            jnp.float32) * 0.02
    return params


def init_discriminator(key, chans: Tuple[int, ...] = (3, 64, 128, 256)) -> Dict:
    """Strided-conv discriminator: 32 -> 16 -> 8 -> 4 -> logit."""
    keys = jax.random.split(key, len(chans))
    params = {}
    for i in range(len(chans) - 1):
        params[f"k{i}"] = jax.random.normal(
            keys[i], (5, 5, chans[i], chans[i + 1]), jnp.float32) * 0.02
    params["head_w"] = jax.random.normal(
        keys[-1], (4 * 4 * chans[-1], 1), jnp.float32) * 0.02
    return params


# --------------------------------------------------------------------------
# Forward passes.
# --------------------------------------------------------------------------

def dcgan_generator(params: Dict, z, engine: str = "huge2",
                    layers=None):
    """z: (B, Z_DIM) -> images (B, 32, 32, 3) in [-1, 1]."""
    layers = layers or DCGAN_LAYERS
    first = layers[0]
    b = z.shape[0]
    x = (z @ params["proj_w"]).reshape(b, first.h, first.h, first.c_in)
    x = jax.nn.relu(x)
    for i, layer in enumerate(layers):
        x = deconv(x, params[f"k{i}"], layer, engine)
        x = jnp.tanh(x) if i == len(layers) - 1 else jax.nn.relu(x)
    return x


def cgan_generator(params: Dict, z, y_onehot, engine: str = "huge2",
                   layers=None):
    """z: (B, Z_DIM), y_onehot: (B, N_CLASSES) -> (B, 32, 32, 3)."""
    layers = layers or CGAN_LAYERS
    first = layers[0]
    zc = jnp.concatenate([z, y_onehot], axis=-1)
    b = z.shape[0]
    x = (zc @ params["proj_w"]).reshape(b, first.h, first.h, first.c_in)
    x = jax.nn.relu(x)
    for i, layer in enumerate(layers):
        x = deconv(x, params[f"k{i}"], layer, engine)
        x = jnp.tanh(x) if i == len(layers) - 1 else jax.nn.relu(x)
    return x


def discriminator(params: Dict, img):
    """img: (B, 32, 32, 3) -> logits (B, 1)."""
    x = img
    i = 0
    while f"k{i}" in params:
        x = ref.conv2d(x, params[f"k{i}"], stride=2, pad=2)
        x = jax.nn.leaky_relu(x, 0.2)
        i += 1
    b = x.shape[0]
    return x.reshape(b, -1) @ params["head_w"]


def atrous_pyramid(x, ks, dilations=(1, 2, 4, 8), engine: str = "huge2"):
    """Semantic-segmentation-style atrous spatial pyramid (paper §1 / §2.1.2
    motivation): parallel dilated convs, summed.  x: (B,H,W,C),
    ks: list of (3,3,C,N) kernels, 'same' output size."""
    outs = []
    for k, d in zip(ks, dilations):
        pad = d  # 3x3 kernel, 'same'
        if engine == "huge2":
            outs.append(conv2d_dilated_huge2(x, k, dilation=d, stride=1,
                                             pad=pad))
        else:
            outs.append(ref.conv2d_dilated_zerofill(x, k, dilation=d,
                                                    stride=1, pad=pad))
    return sum(outs)


# --------------------------------------------------------------------------
# Tiny-DCGAN training step (for the e2e training example).
#
# Channel counts are Table-1 / 8 so a few hundred SGD steps run in seconds
# on the CPU PJRT client; the *structure* (two stride-2 5x5 deconvs, strided
# disc, alternating SGD) is the paper's.
# --------------------------------------------------------------------------

TINY_LAYERS: List[DeconvLayer] = [
    DeconvLayer("tiny_dc1", 8, 64, 32, 5, 2, 2, 1),
    DeconvLayer("tiny_dc2", 16, 32, 3, 5, 2, 2, 1),
]
TINY_Z = 32


def init_tiny_gan(key):
    kg, kd = jax.random.split(key)
    gen = init_dcgan_generator(kg, layers=TINY_LAYERS, z_dim=TINY_Z)
    disc = init_discriminator(kd, chans=(3, 32, 64, 128))
    return gen, disc


def _bce_logits(logits, label: float):
    # label in {0., 1.}; numerically stable BCE-with-logits.
    return jnp.mean(jnp.maximum(logits, 0) - logits * label
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def gan_train_step(gen: Dict, disc: Dict, z, real, lr: float = 0.05):
    """One alternating-SGD GAN step on the tiny model.

    Returns (new_gen, new_disc, loss_g, loss_d).  The generator forward
    uses the oracle engine here: `jax.grad` through the huge2 engine is
    numerically identical but lowers a much larger HLO; the *training
    experiments* (Fig 8 right) benchmark the huge2 gradient kernels
    directly in rust (`deconv::grad`) and in `kernels/dilated.py`.
    """
    def loss_d_fn(dp):
        fake = dcgan_generator(gen, z, engine="oracle", layers=TINY_LAYERS)
        l_real = _bce_logits(discriminator(dp, real), 1.0)
        l_fake = _bce_logits(discriminator(dp, fake), 0.0)
        return l_real + l_fake

    def loss_g_fn(gp):
        fake = dcgan_generator(gp, z, engine="oracle", layers=TINY_LAYERS)
        return _bce_logits(discriminator(disc, fake), 1.0)

    loss_d, gd = jax.value_and_grad(loss_d_fn)(disc)
    new_disc = {k: v - lr * gd[k] for k, v in disc.items()}
    loss_g, gg = jax.value_and_grad(loss_g_fn)(gen)
    new_gen = {k: v - lr * gg[k] for k, v in gen.items()}
    return new_gen, new_disc, loss_g, loss_d
