"""Pure-jnp correctness oracles for the HUGE2 kernels.

Canonical conventions (shared by every layer of the stack — python pallas
kernels, rust baseline, rust huge2):

* Tensors are NHWC: ``x[b, h, w, c]`` with ``b`` usually 1.
* Kernels are HWIO: ``k[r, s, c_in, c_out]``.
* All convolutions are cross-correlations (no kernel flip), matching
  Algorithm 1 / Algorithm 2 of the paper.

Transposed convolution (paper Alg. 1, "zero-insertion" definition):
the input is dilated by the stride (``s-1`` zeros between every pair of
rows/cols), padded asymmetrically by ``(R-1-p, R-1-p+op)`` and then a
stride-1 valid cross-correlation with the kernel is applied.  With
``R=5, s=2, p=2, op=1`` this is exactly the DCGAN 2x upsampling layer:
``H -> 2H``.

Dilated convolution (paper Alg. 2): the *kernel* is dilated by the
dilation factor ``d``; stride and symmetric padding as usual.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

# NHWC activations, HWIO kernels.
DIMS = ("NHWC", "HWIO", "NHWC")


def out_size_transpose(h: int, stride: int, r: int, pad: int, out_pad: int) -> int:
    """Spatial output size of the canonical transposed convolution."""
    return (h - 1) * stride - 2 * pad + r + out_pad


def out_size_dilated(h: int, r: int, dilation: int, stride: int, pad: int) -> int:
    """Spatial output size of the canonical dilated convolution."""
    eff = (r - 1) * dilation + 1
    return (h + 2 * pad - eff) // stride + 1


def conv2d(x, k, stride: int = 1, pad: int = 0):
    """Standard cross-correlation. x: (B,H,W,C), k: (R,S,C,N)."""
    return lax.conv_general_dilated(
        x, k,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=DIMS,
    )


def conv2d_transpose(x, k, stride: int = 2, pad: int = 2, out_pad: int = 1):
    """Canonical transposed convolution via lhs-dilation (the oracle).

    Equivalent to: inflate x with (stride-1) zeros between elements, pad by
    (R-1-pad) low / (R-1-pad+out_pad) high, then valid cross-correlate.
    """
    r = k.shape[0]
    s = k.shape[1]
    lo_h, hi_h = r - 1 - pad, r - 1 - pad + out_pad
    lo_w, hi_w = s - 1 - pad, s - 1 - pad + out_pad
    return lax.conv_general_dilated(
        x, k,
        window_strides=(1, 1),
        padding=[(lo_h, hi_h), (lo_w, hi_w)],
        lhs_dilation=(stride, stride),
        dimension_numbers=DIMS,
    )


def conv2d_transpose_zerofill(x, k, stride: int = 2, pad: int = 2, out_pad: int = 1):
    """Second, independent oracle: literally materialise the zero-inserted
    input tensor (the DarkNet/naive baseline algorithm) and run a dense
    stride-1 convolution over it.  This is the algorithm HUGE2 beats; it is
    also the numeric ground truth the decomposition must match exactly.
    """
    b, h, w, c = x.shape
    r, s, _, _ = k.shape
    ih = (h - 1) * stride + 1
    iw = (w - 1) * stride + 1
    inflated = jnp.zeros((b, ih, iw, c), x.dtype)
    inflated = inflated.at[:, ::stride, ::stride, :].set(x)
    lo_h, hi_h = r - 1 - pad, r - 1 - pad + out_pad
    lo_w, hi_w = s - 1 - pad, s - 1 - pad + out_pad
    padded = jnp.pad(inflated, ((0, 0), (lo_h, hi_h), (lo_w, hi_w), (0, 0)))
    return conv2d(padded, k, stride=1, pad=0)


def conv2d_dilated(x, k, dilation: int = 2, stride: int = 1, pad: int = 0):
    """Canonical dilated (atrous) cross-correlation."""
    return lax.conv_general_dilated(
        x, k,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        rhs_dilation=(dilation, dilation),
        dimension_numbers=DIMS,
    )


def conv2d_dilated_zerofill(x, k, dilation: int = 2, stride: int = 1, pad: int = 0):
    """Independent oracle: materialise the zero-dilated kernel and run a
    standard convolution (the naive baseline for Alg. 2)."""
    r, s, c, n = k.shape
    er = (r - 1) * dilation + 1
    es = (s - 1) * dilation + 1
    dk = jnp.zeros((er, es, c, n), k.dtype)
    dk = dk.at[::dilation, ::dilation, :, :].set(k)
    return conv2d(x, dk, stride=stride, pad=pad)


def weight_grad_dilated(x, dy, stride: int = 2, pad: int = 2,
                        r: int = 5, s: int = 5):
    """Discriminator weight gradient as a dilated convolution (paper 3.2.3).

    For a forward strided conv  y = conv(x, k, stride, pad)  with kernel
    (R,S,C,N), the gradient dL/dk is the correlation of x with the
    stride-dilated derivative maps dy:

        dk[m, n, c, j] = sum_{b,oh,ow} x[b, m + oh*stride - pad,
                                          n + ow*stride - pad, c]
                         * dy[b, oh, ow, j]

    Implemented with lax with C playing the batch role; this is the oracle
    the rust ``deconv::grad`` path and the pallas kernel must match.
    """
    # x:(B,H,W,C) -> lhs:(C,H,W,B); dy:(B,OH,OW,N) -> rhs:(OH,OW,B,N)
    lhs = jnp.transpose(x, (3, 1, 2, 0))
    rhs = jnp.transpose(dy, (1, 2, 0, 3))
    out = lax.conv_general_dilated(
        lhs, rhs,
        window_strides=(1, 1),
        padding=[(pad, pad), (pad, pad)],
        rhs_dilation=(stride, stride),
        dimension_numbers=DIMS,
    )
    # out: (C, R', S', N) -> (R, S, C, N).  R' >= R when (H+2p-R) % stride
    # != 0 (trailing input rows unused by the forward conv) — crop.
    return jnp.transpose(out, (1, 2, 0, 3))[:r, :s]


def input_grad_transpose(dy, k, stride: int = 2, pad: int = 2, out_pad: int = 1):
    """Generator-side backward: dL/dx of a forward strided conv is exactly a
    transposed convolution of dy with the spatially-flipped kernel (in/out
    channels swapped).  Used by the training benches."""
    kf = k[::-1, ::-1, :, :]
    kf = jnp.transpose(kf, (0, 1, 3, 2))  # (R,S,N,C)
    return conv2d_transpose(dy, kf, stride=stride, pad=pad, out_pad=out_pad)
