"""L1 Pallas kernel: the untangled 1x1-convolution GEMM (paper 3.2).

HUGE2's untangling step turns every decomposed deconvolution pattern into a
set of 1x1 convolutions: for each kernel tap (m, n) the contribution to the
output is a plain matrix multiplication

    (Ho*Wo, C) @ (C, N)   accumulated over taps.

This module provides that GEMM as a Pallas kernel, tiled for the TPU MXU:

* grid = (M/TM, N/TN, K/TK); the K axis is the innermost (sequential)
  grid dimension so a VMEM scratch accumulator carries partial sums.
* Block shapes default to (128, 128, 128) — one MXU-sized tile per step —
  and are shrunk automatically for small operands.
* ``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
  custom-calls, so the kernel is lowered to plain HLO.  On a real TPU the
  same BlockSpecs target the 128x128 systolic array directly (see
  DESIGN.md "Hardware-Adaptation").

Two entry points:

* ``matmul(x, w)``         -> x @ w
* ``matmul_acc(x, w, acc)``-> acc + x @ w   (the tap-accumulation form)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# MXU-native tile; shrunk for small operands.
DEFAULT_TM = 128
DEFAULT_TN = 128
DEFAULT_TK = 128


def _pick_tile(dim: int, pref: int) -> int:
    """Largest power-of-two tile <= pref that keeps padding overhead small."""
    t = pref
    while t > 8 and t > dim:
        t //= 2
    return t


def _pad_to(x, m: int, axis: int):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    """One (TM, TN) output tile; accumulates over the K grid axis."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU matmul on the current (TM, TK) x (TK, TN) blocks; accumulate in
    # f32 scratch regardless of input dtype.
    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _matmul_acc_kernel(x_ref, w_ref, a_ref, o_ref, acc_ref, *, nk: int):
    """Same as _matmul_kernel but seeded with a resident accumulator tile —
    the HUGE2 tap-accumulation: out = acc + x @ w."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = a_ref[...].astype(jnp.float32)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def matmul(x, w, tm: int = DEFAULT_TM, tn: int = DEFAULT_TN, tk: int = DEFAULT_TK):
    """Pallas tiled GEMM: (M, K) @ (K, N) -> (M, N)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {w.shape}"
    tm = _pick_tile(m, tm)
    tn = _pick_tile(n, tn)
    tk = _pick_tile(k, tk)
    xp = _pad_to(_pad_to(x, tm, 0), tk, 1)
    wp = _pad_to(_pad_to(w, tk, 0), tn, 1)
    mp, kp = xp.shape
    _, np_ = wp.shape
    nk = kp // tk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(mp // tm, np_ // tn, nk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def matmul_acc(x, w, acc, tm: int = DEFAULT_TM, tn: int = DEFAULT_TN,
               tk: int = DEFAULT_TK):
    """Pallas tiled GEMM with accumulation: acc + (M, K) @ (K, N).

    This is the primitive every untangled tap of the decomposed
    deconvolution reduces to (paper Fig. 5): the (C,)-column group of N
    kernels forms the (K=C, N) weight matrix, the receptive field forms
    the (M=Ho*Wo, K=C) input matrix, and tap products accumulate.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and acc.shape == (m, n), (x.shape, w.shape, acc.shape)
    tm = _pick_tile(m, tm)
    tn = _pick_tile(n, tn)
    tk = _pick_tile(k, tk)
    xp = _pad_to(_pad_to(x, tm, 0), tk, 1)
    wp = _pad_to(_pad_to(w, tk, 0), tn, 1)
    ap = _pad_to(_pad_to(acc, tm, 0), tn, 1)
    mp, kp = xp.shape
    _, np_ = wp.shape
    nk = kp // tk
    out = pl.pallas_call(
        functools.partial(_matmul_acc_kernel, nk=nk),
        grid=(mp // tm, np_ // tn, nk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), acc.dtype),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=True,
    )(xp, wp, ap)
    return out[:m, :n]


def vmem_bytes(tm: int = DEFAULT_TM, tn: int = DEFAULT_TN,
               tk: int = DEFAULT_TK, dtype_bytes: int = 4) -> int:
    """VMEM footprint of one grid step (x tile + w tile + acc + out tile).

    Used by DESIGN.md / EXPERIMENTS.md to estimate real-TPU residency:
    footprint must stay well under ~16 MiB VMEM per core.
    """
    return dtype_bytes * (tm * tk + tk * tn + 2 * tm * tn)
