"""L1: HUGE2 kernel decomposition of the transposed convolution (paper 3.1)
plus untangling (paper 3.2), built on the Pallas GEMM in ``untangled.py``.

For stride ``s`` the R x S transposed kernel splits into ``s*s`` *patterns*
by row/column parity.  Pattern (phi_y, phi_x) produces exactly the output
polyphase ``O[phi_y::s, phi_x::s]`` and reads only *real* (never
zero-inserted) input elements — so the zero-inflated tensor of the naive
algorithm is never materialised, every multiply-add is effective, and the
polyphase writes are disjoint (no accumulation races; paper 3.1).

Index algebra (1-D; both axes are independent):

    lo      = R - 1 - pad                    # low pad of the inflated input
    a0(phi) = (lo - phi) mod s               # first kernel tap of pattern
    T(phi)  = ceil((R - a0) / s)             # taps per pattern
    delta   = (phi + a0 - lo) / s  (integer) # input offset of tap 0
    O[phi + s*q] = sum_t sum_c I[q + t + delta, c] * K[a0 + s*t, c, :]

Each tap is then *untangled* into a (Q_y*Q_x, C) @ (C, N) Pallas GEMM,
accumulated — the paper's "set of 1x1 convolutions".
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from . import untangled
from .ref import out_size_transpose


def pattern_params(r: int, stride: int, pad: int, phi: int):
    """(a0, taps, delta) for one axis of one pattern — the Section 3.1
    decomposition algebra."""
    lo = r - 1 - pad
    a0 = (lo - phi) % stride
    taps = max(0, math.ceil((r - a0) / stride))
    delta = (phi + a0 - lo) // stride
    assert (phi + a0 - lo) % stride == 0
    return a0, taps, delta


def decompose_kernel(k, stride: int, pad: int):
    """Split k:(R,S,C,N) into the s*s pattern sub-kernels.

    Returns {(phi_y, phi_x): (sub_kernel (Tr,Ts,C,N), delta_y, delta_x)}.
    """
    r, s, _, _ = k.shape
    out = {}
    for phi_y in range(stride):
        a0y, tr, dy = pattern_params(r, stride, pad, phi_y)
        for phi_x in range(stride):
            a0x, ts, dx = pattern_params(s, stride, pad, phi_x)
            sub = k[a0y::stride, a0x::stride, :, :]
            assert sub.shape[0] == tr and sub.shape[1] == ts
            out[(phi_y, phi_x)] = (sub, dy, dx)
    return out


def conv2d_transpose_huge2(x, k, stride: int = 2, pad: int = 2,
                           out_pad: int = 1, tm: int = 128, tn: int = 128,
                           tk: int = 128):
    """HUGE2 transposed convolution: decompose + untangle + scatter.

    x: (B, H, W, C);  k: (R, S, C, N)  ->  (B, Ho, Wo, N)
    Numerically identical to ``ref.conv2d_transpose``.
    """
    b, h, w, c = x.shape
    r, s, _, n = k.shape
    ho = out_size_transpose(h, stride, r, pad, out_pad)
    wo = out_size_transpose(w, stride, s, pad, out_pad)
    out = jnp.zeros((b, ho, wo, n), x.dtype)
    patterns = decompose_kernel(k, stride, pad)

    for (phi_y, phi_x), (sub, dy, dx) in patterns.items():
        q_y = _polyphase_len(ho, stride, phi_y)
        q_x = _polyphase_len(wo, stride, phi_x)
        tr, ts = sub.shape[0], sub.shape[1]
        if q_y == 0 or q_x == 0 or tr == 0 or ts == 0:
            continue
        # Pad the (real, small) input so every tap slice is in range.
        pyl = max(0, -dy)
        pxl = max(0, -dx)
        pyh = max(0, q_y - 1 + tr - 1 + dy - (h - 1))
        pxh = max(0, q_x - 1 + ts - 1 + dx - (w - 1))
        xp = jnp.pad(x, ((0, 0), (pyl, pyh), (pxl, pxh), (0, 0)))

        # Untangle: accumulate one Pallas GEMM per kernel tap.
        acc = jnp.zeros((b * q_y * q_x, n), x.dtype)
        for t_r in range(tr):
            for t_c in range(ts):
                oy = t_r + dy + pyl
                ox = t_c + dx + pxl
                patch = xp[:, oy:oy + q_y, ox:ox + q_x, :]
                lhs = patch.reshape(b * q_y * q_x, c)
                rhs = sub[t_r, t_c]  # (C, N): the regrouped 1x1 kernel
                acc = untangled.matmul_acc(lhs, rhs, acc, tm=tm, tn=tn, tk=tk)
        sub_out = acc.reshape(b, q_y, q_x, n)
        # Scatter/combine (paper Fig. 4): disjoint polyphase writes.
        out = out.at[:, phi_y::stride, phi_x::stride, :].set(sub_out)
    return out


def _polyphase_len(total: int, stride: int, phi: int) -> int:
    """Number of output positions y < total with y % stride == phi."""
    if phi >= total:
        return 0
    return (total - phi + stride - 1) // stride


def flop_count(h: int, w: int, c: int, n: int, r: int, s: int,
               stride: int, pad: int, out_pad: int) -> dict:
    """Effective multiply-add counts: naive zero-inserted algorithm vs the
    HUGE2 decomposition.  Feeds the analytical GPU roofline (memsim) and
    EXPERIMENTS.md — mirrors rust ``memsim::counter``."""
    ho = out_size_transpose(h, stride, r, pad, out_pad)
    wo = out_size_transpose(w, stride, s, pad, out_pad)
    naive = ho * wo * r * s * c * n  # slides over the inflated tensor
    eff = 0
    for phi_y in range(stride):
        _, tr, _ = pattern_params(r, stride, pad, phi_y)
        qy = _polyphase_len(ho, stride, phi_y)
        for phi_x in range(stride):
            _, ts, _ = pattern_params(s, stride, pad, phi_x)
            qx = _polyphase_len(wo, stride, phi_x)
            eff += qy * qx * tr * ts * c * n
    return {"naive_macs": naive, "huge2_macs": eff,
            "ratio": naive / max(eff, 1)}
