"""L1: untangled dilated convolution and GAN-training gradients (paper
3.2.2 / 3.2.3), built on the Pallas GEMM in ``untangled.py``.

Dilated convolution never materialises the zero-dilated kernel: each of the
R*S real taps reads a strided slice of the input and contributes one
(Ho*Wo, C) @ (C, N) GEMM — the receptive field "shrinks by a multiple of
the stride" (paper Fig. 6 left).

The discriminator weight gradient (paper 3.2.3) is the same machinery with
the roles swapped: the derivative map acts as a stride-dilated kernel, so
each of the R*S weight-gradient taps is a (C, Oh*Ow) @ (Oh*Ow, N) GEMM.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import untangled
from .ref import out_size_dilated


def conv2d_dilated_huge2(x, k, dilation: int = 2, stride: int = 1,
                         pad: int = 0, tm: int = 128, tn: int = 128,
                         tk: int = 128):
    """Untangled dilated conv. x: (B,H,W,C), k: (R,S,C,N) -> (B,Ho,Wo,N).

    Numerically identical to ``ref.conv2d_dilated`` — but touches only the
    R*S real kernel taps, never the (R-1)*d+1 square of zeros.
    """
    b, h, w, c = x.shape
    r, s, _, n = k.shape
    ho = out_size_dilated(h, r, dilation, stride, pad)
    wo = out_size_dilated(w, s, dilation, stride, pad)
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))

    acc = jnp.zeros((b * ho * wo, n), x.dtype)
    for t_r in range(r):
        for t_c in range(s):
            oy = t_r * dilation
            ox = t_c * dilation
            # Strided receptive field of this tap (paper Fig. 6 left).
            patch = xp[:, oy:oy + (ho - 1) * stride + 1:stride,
                       ox:ox + (wo - 1) * stride + 1:stride, :]
            lhs = patch.reshape(b * ho * wo, c)
            acc = untangled.matmul_acc(lhs, k[t_r, t_c], acc,
                                       tm=tm, tn=tn, tk=tk)
    return acc.reshape(b, ho, wo, n)


def weight_grad_huge2(x, dy, stride: int = 2, pad: int = 2, r: int = 5,
                      s: int = 5, tm: int = 128, tn: int = 128,
                      tk: int = 128):
    """Discriminator weight gradient via untangling (paper 3.2.3).

    x: (B,H,W,C) forward input;  dy: (B,Oh,Ow,N) derivative maps of a
    forward conv with stride ``stride`` and kernel (r,s,C,N).
    Returns dk: (r,s,C,N).  Each tap (m,n) is one GEMM:
        dk[m,n] = X_mn^T @ DY,  X_mn: (B*Oh*Ow, C), DY: (B*Oh*Ow, N)
    i.e. the derivative map convolves the input as a stride-dilated kernel.
    """
    b, h, w, c = x.shape
    _, oh, ow, n = dy.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    rhs = dy.reshape(b * oh * ow, n)
    taps = []
    for m in range(r):
        row = []
        for nn in range(s):
            patch = xp[:, m:m + (oh - 1) * stride + 1:stride,
                       nn:nn + (ow - 1) * stride + 1:stride, :]
            lhs = patch.reshape(b * oh * ow, c).T  # (C, B*Oh*Ow)
            row.append(untangled.matmul(lhs, rhs, tm=tm, tn=tn, tk=tk))
        taps.append(jnp.stack(row))
    return jnp.stack(taps)  # (r, s, C, N)
