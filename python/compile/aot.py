"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

This is the only place python runs; `make artifacts` invokes it once and
the rust engine is self-contained afterwards.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate binds) rejects (`proto.id() <=
INT_MAX`).  The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Outputs:
    artifacts/<name>.hlo.txt    one per entry point
    artifacts/manifest.txt      name, file, input/output shapes+dtypes
                                (hand-parsed by rust/src/runtime/artifact.rs)
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True, so the
    rust side always unwraps a tuple — uniform for 1..N outputs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest: list[str] = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, arg_specs):
        """Lower fn(*arg_specs) and record it in the manifest."""
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_specs = lowered.out_info
        flat_out, _ = jax.tree_util.tree_flatten(out_specs)
        lines = [f"artifact {name} {fname}"]
        for i, a in enumerate(arg_specs):
            dims = ",".join(str(d) for d in a.shape) or "scalar"
            lines.append(f"input {i} {a.dtype} {dims}")
        for i, o in enumerate(flat_out):
            dims = ",".join(str(d) for d in o.shape) or "scalar"
            lines.append(f"output {i} {o.dtype} {dims}")
        lines.append("end")
        self.manifest.extend(lines)
        print(f"  wrote {fname} ({len(text)} chars, "
              f"{len(arg_specs)} in / {len(flat_out)} out)", flush=True)

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.txt")
        with open(path, "w") as f:
            f.write("\n".join(self.manifest) + "\n")
        print(f"  wrote manifest.txt ({len(self.manifest)} lines)")


def emit_layer_artifacts(em: Emitter):
    """Per-Table-1-layer deconvs, both engines, batch 1 — the quickstart /
    layer-serving units and the rust<->python numeric cross-check points."""
    for layer in model.ALL_LAYERS:
        x = spec(1, layer.h, layer.h, layer.c_in)
        k = spec(layer.k, layer.k, layer.c_in, layer.c_out)
        for engine in ("huge2", "baseline"):
            em.emit(
                f"{layer.name}_{engine}",
                lambda xx, kk, layer=layer, engine=engine:
                    (model.deconv(xx, kk, layer, engine),),
                (x, k),
            )


def emit_generator_artifacts(em: Emitter, batches=(1, 4, 8)):
    """Full DCGAN / cGAN generator forwards (weights are runtime inputs so
    the rust engine seeds/owns them).  One artifact per batch bucket — the
    dynamic batcher routes to the best bucket."""
    dc_first = model.DCGAN_LAYERS[0]
    nk = len(model.DCGAN_LAYERS)
    for b in batches:
        args = [spec(b, model.Z_DIM),
                spec(model.Z_DIM, dc_first.h * dc_first.h * dc_first.c_in)]
        for layer in model.DCGAN_LAYERS:
            args.append(spec(layer.k, layer.k, layer.c_in, layer.c_out))

        def gen(z, proj_w, *ks):
            params = {"proj_w": proj_w}
            params.update({f"k{i}": k for i, k in enumerate(ks)})
            return (model.dcgan_generator(params, z, engine="huge2"),)

        em.emit(f"dcgan_gen_b{b}", gen, args)

    cg_first = model.CGAN_LAYERS[0]
    for b in batches[:2]:
        args = [spec(b, model.Z_DIM), spec(b, model.N_CLASSES),
                spec(model.Z_DIM + model.N_CLASSES,
                     cg_first.h * cg_first.h * cg_first.c_in)]
        for layer in model.CGAN_LAYERS:
            args.append(spec(layer.k, layer.k, layer.c_in, layer.c_out))

        def cgen(z, y, proj_w, *ks):
            params = {"proj_w": proj_w}
            params.update({f"k{i}": k for i, k in enumerate(ks)})
            return (model.cgan_generator(params, z, y, engine="huge2"),)

        em.emit(f"cgan_gen_b{b}", cgen, args)


GEN_KEYS = None  # filled at emit time; deterministic param flattening order
DISC_KEYS = None


def emit_train_artifact(em: Emitter, batch: int = 16):
    """Tiny-DCGAN alternating-SGD train step as one HLO module."""
    global GEN_KEYS, DISC_KEYS
    gen, disc = model.init_tiny_gan(jax.random.PRNGKey(0))
    GEN_KEYS = sorted(gen.keys())
    DISC_KEYS = sorted(disc.keys())

    def step(*flat):
        ng = len(GEN_KEYS)
        nd = len(DISC_KEYS)
        g = dict(zip(GEN_KEYS, flat[:ng]))
        d = dict(zip(DISC_KEYS, flat[ng:ng + nd]))
        z, real = flat[ng + nd], flat[ng + nd + 1]
        new_g, new_d, lg, ld = model.gan_train_step(g, d, z, real)
        return tuple(new_g[k] for k in GEN_KEYS) + \
            tuple(new_d[k] for k in DISC_KEYS) + (lg, ld)

    args = [spec(*gen[k].shape) for k in GEN_KEYS]
    args += [spec(*disc[k].shape) for k in DISC_KEYS]
    args += [spec(batch, model.TINY_Z), spec(batch, 32, 32, 3)]
    em.emit("tiny_gan_step", step, args)

    # init-params artifact: produces the seeded initial weights so rust
    # starts from the exact same point as python would.
    def init_fn():
        g, d = model.init_tiny_gan(jax.random.PRNGKey(0))
        return tuple(g[k] for k in GEN_KEYS) + \
            tuple(d[k] for k in DISC_KEYS)

    em.emit("tiny_gan_init", init_fn, ())


def emit_segment_artifact(em: Emitter):
    """Atrous-pyramid segmentation head (dilated-conv workload, §2.1.2)."""
    c, n, h = 32, 32, 33
    x = spec(1, h, h, c)
    ks = [spec(3, 3, c, n) for _ in range(4)]

    def pyr(xx, *kk):
        return (model.atrous_pyramid(xx, list(kk), engine="huge2"),)

    em.emit("atrous_pyramid", pyr, (x, *ks))

    # single dilated layers, both engines, for numeric cross-checks
    for d in (2, 4):
        for engine in ("huge2", "baseline"):
            def one(xx, kk, d=d, engine=engine):
                if engine == "huge2":
                    from .kernels.dilated import conv2d_dilated_huge2
                    return (conv2d_dilated_huge2(xx, kk, dilation=d,
                                                 stride=1, pad=d),)
                return (ref.conv2d_dilated_zerofill(xx, kk, dilation=d,
                                                    stride=1, pad=d),)
            em.emit(f"dilated_d{d}_{engine}", one, (x, ks[0]))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated group filter: layers,gen,train,segment")
    args = ap.parse_args()
    groups = set(args.only.split(",")) if args.only else None

    em = Emitter(args.out)
    if groups is None or "layers" in groups:
        print("[aot] per-layer artifacts")
        emit_layer_artifacts(em)
    if groups is None or "gen" in groups:
        print("[aot] generator artifacts")
        emit_generator_artifacts(em)
    if groups is None or "train" in groups:
        print("[aot] train-step artifact")
        emit_train_artifact(em)
    if groups is None or "segment" in groups:
        print("[aot] segmentation artifacts")
        emit_segment_artifact(em)
    em.finish()


if __name__ == "__main__":
    sys.exit(main())
